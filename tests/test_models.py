"""Per-arch smoke tests (deliverable f) + decode/forward consistency.

Every assigned architecture instantiates its reduced config and runs one
forward/train step on CPU, asserting output shapes and finiteness.  The
full configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.models import (decode_step, forward, init, init_cache, loss_fn,
                          n_periods, period_slots)

RC = RunConfig(remat=False, attn_impl="naive")
KEY = jax.random.PRNGKey(0)

ALL_ARCHS = sorted(ARCHS)

# Heavyweight reduced configs (>~4s per jitted train step on CI CPU):
# their end-to-end smokes carry the @slow marker and run in the dedicated
# CI slow job, keeping the default tier-1 gate fast.  The cheap archs
# stay in the default run so every test session still compiles + steps
# real models.
SLOW_ARCHS = frozenset({
    "jamba-1.5-large-398b", "mamba2-780m", "qwen2-moe-a2.7b",
    "llama-3.2-vision-90b", "minitron-4b", "llama4-scout-17b-a16e",
    "musicgen-large", "qwen2-7b", "mistral-nemo-12b",
})


def _slow_param(arch):
    return pytest.param(arch, marks=pytest.mark.slow) \
        if arch in SLOW_ARCHS else arch


def _batch(cfg, b=2, l=32, key=KEY):
    if cfg.family == "audio":
        tok = jax.random.randint(key, (b, l, cfg.audio.n_codebooks), 0,
                                 cfg.vocab)
    else:
        tok = jax.random.randint(key, (b, l), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": tok}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.vision.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_forward_shapes_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    params = init(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = forward(params, batch["tokens"], cfg, RC,
                          image_embeds=batch.get("image_embeds"))
    if cfg.family == "audio":
        assert logits.shape == (2, 32, cfg.audio.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", [_slow_param(a) for a in ALL_ARCHS])
def test_arch_smoke_train_step(arch):
    from repro.train import make_train_step
    from repro.optim import make_optimizer
    cfg = reduced(ARCHS[arch])
    params = init(KEY, cfg)
    opt_init, _ = make_optimizer("adamw")
    opt = opt_init(params)
    rc = RunConfig(remat=False, attn_impl="naive", learning_rate=1e-2,
                   warmup_steps=1)
    step = jax.jit(make_train_step(cfg, rc))
    # step 1: past warmup, lr > 0, update visible in bf16
    p2, o2, metrics = step(params, opt, _batch(cfg), jnp.int32(1))
    assert jnp.isfinite(metrics["loss"])
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", [
    "qwen2-7b", "mamba2-780m",
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
    pytest.param("qwen2-moe-a2.7b", marks=pytest.mark.slow)])
def test_decode_matches_forward(arch):
    """Prefill-by-decode then compare each step's logits to the full
    forward — exercises KV caches, mamba state recurrences, rope offsets.

    MoE capacity is raised so no tokens drop (batched dispatch drops
    differently than single-token decode — expected capacity-MoE
    behaviour, not a cache bug)."""
    import dataclasses
    cfg = reduced(ARCHS[arch])
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init(KEY, cfg)
    b, l = 2, 12
    toks = jax.random.randint(KEY, (b, l), 0, cfg.vocab)
    full_logits, _ = forward(params, toks, cfg, RC)

    cache = init_cache(cfg, RC, b, 16)
    outs = []
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, RC))
    for t in range(l):
        lg, cache = step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=0.08, atol=0.08)


def test_decode_int8_cache_close_to_bf16():
    cfg = reduced(ARCHS["qwen2-7b"])
    params = init(KEY, cfg)
    b, l = 2, 8
    toks = jax.random.randint(KEY, (b, l), 0, cfg.vocab)
    outs = {}
    for dt in ("bfloat16", "int8"):
        rc = RunConfig(remat=False, attn_impl="naive", kv_cache_dtype=dt)
        cache = init_cache(cfg, rc, b, 16)
        step = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, cfg, rc))
        for t in range(l):
            lg, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.int32(t))
        outs[dt] = np.asarray(lg, np.float32)
    # int8 cache quantization noise stays small
    rel = np.abs(outs["int8"] - outs["bfloat16"]).max() / (
        np.abs(outs["bfloat16"]).max() + 1e-6)
    assert rel < 0.12, rel


def test_period_structure():
    assert len(period_slots(ARCHS["jamba-1.5-large-398b"])) == 8
    assert n_periods(ARCHS["jamba-1.5-large-398b"]) == 9
    assert len(period_slots(ARCHS["llama-3.2-vision-90b"])) == 5
    assert n_periods(ARCHS["llama-3.2-vision-90b"]) == 20
    assert n_periods(ARCHS["qwen2-7b"]) == 28


def test_param_counts_roughly_match_names():
    """Config param counts land near the advertised sizes."""
    approx = {
        "qwen2-7b": 7.6e9, "qwen1.5-32b": 32e9, "mistral-nemo-12b": 12e9,
        "minitron-4b": 4.2e9, "mamba2-780m": 0.78e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for name, want in approx.items():
        got = ARCHS[name].param_count()
        assert 0.55 * want < got < 1.7 * want, (name, got, want)


def test_moe_routing_conservation():
    """Disabling noise: MoE output is a convex combination per token."""
    from repro.models.moe import moe_apply, moe_init
    cfg = reduced(ARCHS["qwen2-moe-a2.7b"])
    p = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(aux) and aux >= 0


def test_mamba_chunked_matches_stepwise():
    """SSD chunked scan == token-by-token recurrence (the duality)."""
    from repro.models.mamba2 import ssd_chunked, ssd_decode_step
    b, l, h, p, n = 1, 16, 2, 4, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, l, 1, n), jnp.float32) * 0.5
    C = jax.random.normal(ks[4], (b, l, 1, n), jnp.float32) * 0.5
    y_chunk, fin = ssd_chunked(x, dt, A, B, C, chunk=4)
    st = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(l):
        yt, st = ssd_decode_step(st, x[:, t], dt[:, t], A, B[:, t],
                                 C[:, t])
        ys.append(yt)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(st),
                               rtol=2e-4, atol=2e-4)
