"""Property-based differential harness for the planner core.

Pins the three sweep backends to each other — scalar Python cost model,
XLA-vectorized `evaluate_flat`, and the fused Pallas kernel
(`kernels.sweep_eval`) — over hypothesis-generated inputs: GEMM shapes
including degenerate M/N/K = 1 and non-power-of-two dims, every
standard config, and both DRAM order modes.  The batched backends share
one cost spec (vectorized.cim_*) but lower through entirely different
compilation pipelines, so agreement here is evidence about the kernels,
not about shared code paths; the scalar model is the independent
reference implementation.

Offline tier-1 runs these through tests/_hypothesis_stub.py (boundary
values first, deterministic draws); CI runs them under real hypothesis.
"""
import functools

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import GEMM, decide, evaluate, standard_configs
from repro.core.sweep import SweepEngine
from repro.core.vectorized import FLAT_FIELDS, MAP_FIELDS, config_row, \
    evaluate_flat
from repro.kernels.sweep_eval import sweep_eval

CONFIGS = standard_configs()
CONFIG_NAMES = sorted(CONFIGS)

# One engine for the whole module: vectorized and pallas results live in
# separate result-cache keyspaces, so every pallas query really runs the
# Pallas kernel (module-level instead of the conftest fixture — the stub's
# @given wrapper takes no pytest fixtures).
ENGINE = SweepEngine(mesh=None)

# Shape pool: the degenerate GEMV corner (1), awkward primes/non-pow2
# sizes (3, 17, 31, 100, 257, 300), and pow2 paper-scale dims.  The low
# boundary corner is the all-ones GEMM, generated first by both real
# hypothesis (shrink target) and the stub (boundary-first).
DIMS = (1, 3, 17, 31, 64, 100, 257, 300, 1024, 4096)
dim = st.sampled_from(DIMS)
gemm_shape = st.tuples(dim, dim, dim)

# the widened What axis: every precision the cost model supports, as
# (bits, fp) pairs.  INT8 first: it is the Table-IV calibration identity
# and the boundary case both real hypothesis and the stub emit first.
PRECISIONS = ((8, False), (4, False), (8, True))
precision = st.sampled_from(PRECISIONS)


@st.composite
def cim_cases(draw):
    """(GEMM, config name, order_mode): one planner cost-model query.

    Draws span the full widened grid: GEMM shape x precision
    (INT8/INT4/FP8) x config (all four Table-IV prototypes — both
    analog and digital kinds — at RF/SMEM-A/SMEM-B) x order mode."""
    m, n, k = draw(gemm_shape)
    bits, fp = draw(precision)
    name = draw(st.sampled_from(CONFIG_NAMES))
    greedy = draw(st.booleans())
    return (GEMM(m, n, k, bits=bits, fp=fp), name,
            "greedy" if greedy else "exact")


@given(case=cim_cases())
@settings(max_examples=16, deadline=None)
def test_metric_parity_scalar_vs_vectorized_vs_pallas(case):
    """Per-(GEMM, config) metrics agree across all three backends: the
    two batched kernels within float32 round-off of each other, both
    within tolerance of the float64 scalar reference."""
    g, name, om = case
    cfg = CONFIGS[name]
    ms = evaluate(g, cfg, om)
    mv = ENGINE.cim_metrics([(g, cfg)], om, backend="vectorized")[0]
    mp = ENGINE.cim_metrics([(g, cfg)], om, backend="pallas")[0]
    assert mp.energy_pj == pytest.approx(mv.energy_pj, rel=1e-5), (g, name)
    assert mp.time_ns == pytest.approx(mv.time_ns, rel=1e-5), (g, name)
    assert mp.dram_bytes == pytest.approx(mv.dram_bytes, rel=1e-5)
    assert mv.energy_pj == pytest.approx(ms.energy_pj, rel=0.02), (g, name)
    assert mv.time_ns == pytest.approx(ms.time_ns, rel=0.02), (g, name)
    assert mp.energy_pj == pytest.approx(ms.energy_pj, rel=0.02), (g, name)


def _tie_ok(name_a, name_b, decision, tol=0.02):
    """Verdicts may differ only on float32 near-ties of the objective."""
    def topsw(name):
        return (decision.baseline.tops_per_w if name == "baseline"
                else decision.options[name].tops_per_w)
    ta, tb = topsw(name_a), topsw(name_b)
    return abs(ta - tb) <= tol * max(ta, tb)


@given(shape=st.tuples(st.sampled_from(DIMS[:8]), st.sampled_from(DIMS[:8]),
                       st.sampled_from(DIMS[:8])),
       prec=precision, greedy=st.booleans())
@settings(max_examples=4, deadline=None)
def test_verdict_parity_three_backends(shape, prec, greedy):
    """Full decide() verdicts (what/when/where over all 12 standard
    configs + baseline) agree across scalar, vectorized and pallas —
    at every precision of the widened What axis."""
    g = GEMM(*shape, bits=prec[0], fp=prec[1])
    om = "greedy" if greedy else "exact"
    ds = decide(g, CONFIGS, order_mode=om, backend="scalar")
    dv = decide(g, CONFIGS, order_mode=om, backend="vectorized")
    dp = decide(g, CONFIGS, order_mode=om, backend="pallas")
    assert dp.use_cim == dv.use_cim == ds.use_cim, (g, om)
    assert (dp.best_energy == dv.best_energy
            or _tie_ok(dp.best_energy, dv.best_energy, ds)), (g, om)
    assert (dv.best_energy == ds.best_energy
            or _tie_ok(dv.best_energy, ds.best_energy, ds)), (g, om)


# --- raw-row differential: XLA kernel vs Pallas kernel ----------------------
# candidate_mappings only emits pre-validated rows, so the engine-level
# tests above never exercise the kernels' invalid-row handling.  Here the
# mapping fields are drawn wide (beyond array bounds, over-capacity,
# over-provisioned primitives), rows mix configs freely, and the two
# kernels must agree bitwise on the full output dict — valid mask, inf
# fills and all.

_N_RAW_ROWS = 16          # fixed row count -> one trace per (mode, kernel)
# jitted once at module scope: a fresh jax.jit per example would recompile
# the kernels 2 x max_examples times
_RAW_FNS = {om: (jax.jit(functools.partial(evaluate_flat, order_mode=om)),
                 jax.jit(functools.partial(sweep_eval, order_mode=om)))
            for om in ("exact", "greedy")}
map_field = st.sampled_from((1, 2, 5, 7, 16, 64, 253, 1024, 4096))
raw_row = st.tuples(dim, dim, dim,                      # M, N, K
                    map_field, map_field,               # k_arr, n_arr
                    map_field, map_field,               # pk, pn
                    map_field, map_field, map_field,    # m1, fk, fn
                    st.sampled_from(CONFIG_NAMES),
                    precision)                          # (bits, fp)


def _raw_batch(rows):
    batch = {f: [] for f in FLAT_FIELDS}
    for row in rows:
        m, n, k = row[0], row[1], row[2]
        bits, fp = row[11]
        vals = dict(zip(MAP_FIELDS, row[3:10]))
        vals.update({"M": m, "N": n, "K": k, "bits": bits, "is_fp": int(fp)},
                    **config_row(CONFIGS[row[10]]))
        for f in FLAT_FIELDS:
            batch[f].append(float(vals[f]))
    return {f: np.asarray(v, np.float32) for f, v in batch.items()}


@given(rows=st.lists(raw_row, min_size=_N_RAW_ROWS, max_size=_N_RAW_ROWS),
       greedy=st.booleans())
@settings(max_examples=10, deadline=None)
def test_raw_rows_xla_vs_pallas_bitwise(rows, greedy):
    om = "greedy" if greedy else "exact"
    batch = _raw_batch(rows)
    fn_x, fn_p = _RAW_FNS[om]
    out_x = fn_x(batch)
    out_p = fn_p(batch)
    assert set(out_p) == set(out_x)
    for key in out_x:
        a, b = np.asarray(out_x[key]), np.asarray(out_p[key])
        assert np.array_equal(a, b, equal_nan=True), (
            key, om, a[:4], b[:4])
    # degenerate/invalid rows must be flagged, not scored: any row whose
    # mapping exceeds the array bounds is invalid in BOTH kernels
    k_over = batch["k_arr"] > batch["k_rows"]
    assert not np.asarray(out_p["valid"])[k_over].any()


@pytest.mark.slow
def test_full_grid_three_backend_parity_exhaustive():
    """The @slow full-grid gate: EVERY (shape, precision, order-mode)
    combination of a representative shape set — degenerate GEMV,
    awkward primes, paper-scale pow2 — decided by all three backends
    over all 12 standard configs + baseline, no sampling.  The fast
    tier draws from this grid; this job walks it exhaustively."""
    shapes = ((1, 1, 1), (1, 4096, 4096), (17, 100, 300),
              (64, 1024, 4096), (300, 257, 31), (1024, 1024, 1024))
    for shape in shapes:
        for bits, fp in PRECISIONS:
            g = GEMM(*shape, bits=bits, fp=fp)
            for om in ("exact", "greedy"):
                ds = decide(g, CONFIGS, order_mode=om, backend="scalar")
                dv = decide(g, CONFIGS, order_mode=om,
                            backend="vectorized")
                dp = decide(g, CONFIGS, order_mode=om, backend="pallas")
                assert dp.use_cim == dv.use_cim == ds.use_cim, (g, om)
                assert (dp.best_energy == dv.best_energy
                        or _tie_ok(dp.best_energy, dv.best_energy, ds)), (
                    g, om)
                assert (dv.best_energy == ds.best_energy
                        or _tie_ok(dv.best_energy, ds.best_energy, ds)), (
                    g, om)


def test_degenerate_all_ones_gemm_all_backends():
    """M=N=K=1 (the boundary corner the strategies shrink to) is valid,
    finite, and identically scored by every backend on every config and
    both order modes."""
    g = GEMM(1, 1, 1)
    for om in ("exact", "greedy"):
        for name in CONFIG_NAMES:
            cfg = CONFIGS[name]
            ms = evaluate(g, cfg, om)
            mv = ENGINE.cim_metrics([(g, cfg)], om, "vectorized")[0]
            mp = ENGINE.cim_metrics([(g, cfg)], om, "pallas")[0]
            assert np.isfinite(ms.energy_pj)
            assert mp.energy_pj == pytest.approx(mv.energy_pj, rel=1e-5)
            assert mv.energy_pj == pytest.approx(ms.energy_pj, rel=0.02), (
                name, om)
