"""Minimal, deterministic stand-in for the `hypothesis` API surface the
test-suite uses, registered by conftest.py ONLY when the real package is
not installed (e.g. offline containers).  CI installs real hypothesis from
the `test` extra in pyproject.toml and never sees this module.

Supported surface:
  @given(*strategies, **named_strategies)
  @settings(max_examples=..., deadline=...)
  strategies.integers(min_value, max_value) / integers(lo, hi)
  strategies.sampled_from(seq)
  strategies.lists(elem_strategy, min_size=, max_size=)
  strategies.booleans()
  strategies.tuples(*elem_strategies)
  @strategies.composite  (draw-based strategies, positional/kw args)

Example generation is deterministic (seeded per test name) and always
includes the strategy's boundary values first, so property tests exercise
the same edge cases on every run.  No shrinking — on failure the
falsifying example is attached to the raised error.
"""
from __future__ import annotations

import itertools
import random
import zlib


class _Strategy:
    def boundary(self):                      # high-value examples, tried first
        return []

    def example(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def boundary(self):
        return [self.lo, self.hi] if self.hi > self.lo else [self.lo]

    def example(self, rng):
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, elems):
        self.elems = list(elems)
        if not self.elems:
            raise ValueError("sampled_from requires a non-empty sequence")

    def boundary(self):
        return [self.elems[0], self.elems[-1]]

    def example(self, rng):
        return rng.choice(self.elems)


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=None):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 8

    def boundary(self):
        b = self.elem.boundary() or [self.elem.example(random.Random(0))]
        return [[b[0]] * self.min_size, [b[-1]] * self.max_size]

    def example(self, rng):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(n)]


class _Booleans(_Strategy):
    def boundary(self):
        return [False, True]

    def example(self, rng):
        return rng.random() < 0.5


class _Tuples(_Strategy):
    def __init__(self, elems):
        self.elems = list(elems)

    def boundary(self):
        # low-corner and high-corner tuples: exercises the degenerate
        # all-minimum case (e.g. M=N=K=1 GEMMs) before any random draw
        rng = random.Random(0)
        lo = tuple((s.boundary() or [s.example(rng)])[0] for s in self.elems)
        hi = tuple((s.boundary() or [s.example(rng)])[-1] for s in self.elems)
        return [lo, hi]

    def example(self, rng):
        return tuple(s.example(rng) for s in self.elems)


class _Composite(_Strategy):
    """Draw-based strategy: `fn(draw, *args, **kwargs)` where draw(s)
    samples sub-strategy s.  Boundary generation routes every draw to the
    sub-strategies' own boundary values (low corner, then high corner)."""

    def __init__(self, fn, args, kwargs):
        self.fn, self.args, self.kwargs = fn, args, kwargs

    def boundary(self):
        out = []
        for pick in (0, -1):
            rng = random.Random(pick)

            def draw(s, _p=pick, _rng=rng):
                b = s.boundary()
                return b[_p] if b else s.example(_rng)

            out.append(self.fn(draw, *self.args, **self.kwargs))
        return out

    def example(self, rng):
        return self.fn(lambda s: s.example(rng), *self.args, **self.kwargs)


class strategies:                            # mirrors `hypothesis.strategies`
    @staticmethod
    def integers(min_value=None, max_value=None):
        lo = -(2 ** 16) if min_value is None else min_value
        hi = 2 ** 16 if max_value is None else max_value
        return _Integers(lo, hi)

    @staticmethod
    def sampled_from(elems):
        return _SampledFrom(elems)

    @staticmethod
    def lists(elem, min_size=0, max_size=None):
        return _Lists(elem, min_size=min_size, max_size=max_size)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def tuples(*elems):
        return _Tuples(elems)

    @staticmethod
    def composite(fn):
        def make(*args, **kwargs):
            return _Composite(fn, args, kwargs)
        make.__name__ = getattr(fn, "__name__", "composite")
        make.__doc__ = fn.__doc__
        return make


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*pos_strats, **named_strats):
    def deco(fn):
        max_examples = getattr(fn, "_stub_settings",
                               {"max_examples": 100})["max_examples"]
        rng = random.Random(zlib.crc32(fn.__name__.encode()))

        names = list(named_strats)
        strats = list(pos_strats) + [named_strats[n] for n in names]

        def draw_examples():
            # boundary combinations first (diagonal, not the full product),
            # then deterministic random draws up to max_examples.
            bounds = [s.boundary() or [s.example(rng)] for s in strats]
            for combo in itertools.islice(
                    zip(*[itertools.cycle(b) for b in bounds]),
                    min(max_examples, max(len(b) for b in bounds))):
                yield list(combo)
            while True:
                yield [s.example(rng) for s in strats]

        def wrapper():
            for i, values in enumerate(
                    itertools.islice(draw_examples(), max_examples)):
                pos = values[:len(pos_strats)]
                kw = dict(zip(names, values[len(pos_strats):]))
                try:
                    fn(*pos, **kw)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i}): args={pos} "
                        f"kwargs={kw}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


HealthCheck = type("HealthCheck", (), {"all": staticmethod(lambda: [])})
