"""Phase-split gating: per-phase plan tables, one executable per phase,
and the widened-precision (INT4/FP8) gated execution routes.

The serving stack plans prefill and decode as separate workloads
(planner.plan_workload_by_phase over llm_workloads.phase_gemms_of_model):
prefill GEMMs carry M = seq_len reuse while decode GEMMs collapse to
M = batch, so their What/When verdicts legitimately differ.  The
contracts under test:

  * a mixed-verdict architecture (mamba2's ssm-BCdt projection at
    batch 8 / seq 2048) really produces different prefill and decode
    verdict tables, and the core gates each phase by its own table;
  * each phase compiles exactly ONE executable — and when the phases
    gate every projection identically the execution tables are aliased,
    so both phases share one program instead of lowering a redundant
    second copy;
  * an empty phase workload raises instead of silently disabling gating
    (plan_workload_by_phase's zero-GEMM guard);
  * the widened What axis at runtime: quantize=True sessions at
    precision="int4" / "fp8" route gated projections through the
    low-bit CiM Pallas paths and match the ungated program's logits —
    routing is the only difference, same quantized weights.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.core.llm_workloads import (is_projection_label,
                                      phase_gemms_of_model)
from repro.core.planner import plan_workload_by_phase
from repro.models import init
from repro.serving import ContinuousBatchingEngine, Request, ServeSession
from repro.serving.core import DecodeCore

RC = RunConfig(remat=False, attn_impl="naive")

# the mixed-verdict serving shape: at plan_batch=8 / plan_max_len=2048
# the reduced mamba2 ssm-BCdt projection gate flips between phases
# (prefill's M=2048 reuse earns CiM a different verdict than decode's
# M=8 GEMV) while e.g. batch 4 gates both phases identically.
MIXED_ARCH, MIXED_BATCH, MIXED_LEN = "mamba2-780m", 8, 2048


# --- per-phase planning ------------------------------------------------------

def test_phase_tables_differ_on_mixed_verdict_arch():
    """The two serving phases produce genuinely different verdict
    tables on the mixed arch, the flip is a *projection* label (a gate
    the runtime actually consults), and the quantized core wires each
    phase's execution table from its own verdicts."""
    cfg = reduced(ARCHS[MIXED_ARCH])
    core = DecodeCore(cfg, RC, None, plan_batch=MIXED_BATCH,
                      plan_max_len=MIXED_LEN)
    tables = core.phase_verdict_tables
    assert set(tables) == {"prefill", "decode"}
    flips = tables["decode"].flips(tables["prefill"])
    proj_flips = [lab for lab in flips if is_projection_label(lab)]
    assert "ssm-BCdt" in proj_flips, flips
    # verdict_table stays the decode phase's view
    assert core.verdict_table == tables["decode"]


def test_phase_gemms_of_model_shapes():
    """Prefill GEMMs carry M = seq_len, decode GEMMs M = batch — the
    structural asymmetry the per-phase verdicts come from."""
    cfg = ARCHS["mistral-nemo-12b"]
    phases = phase_gemms_of_model(cfg, 2048, 8)
    pre = {g.label: g for g in phases["prefill"]}
    dec = {g.label: g for g in phases["decode"]}
    assert pre[f"{cfg.name} Wq"].M == 2048
    assert dec[f"{cfg.name} Wq"].M == 8
    # same projection label set in both phases (activation-score labels
    # may legitimately differ per phase)
    pp = {l for l in pre if is_projection_label(l)}
    dp = {l for l in dec if is_projection_label(l)}
    assert pp == dp


def test_plan_workload_by_phase_empty_phase_raises():
    """A phase with zero eligible GEMMs must raise, not return an empty
    plan — an empty aggregate would silently ungate that phase."""
    cfg = ARCHS["mistral-nemo-12b"]
    phases = phase_gemms_of_model(cfg, 64, 2)
    with pytest.raises(ValueError, match="zero eligible GEMMs"):
        plan_workload_by_phase({**phases, "decode": []})
    with pytest.raises(ValueError, match="at least one phase"):
        plan_workload_by_phase({})


# --- one executable per phase ------------------------------------------------

def test_mixed_verdict_core_compiles_one_executable_per_phase():
    """On the mixed arch the phases gate differently -> two distinct
    plan tables, two programs — but each phase still compiles exactly
    once, no matter how much traffic runs through it."""
    cfg = reduced(ARCHS[MIXED_ARCH])
    params = init(jax.random.PRNGKey(1), cfg)
    s = ServeSession(cfg, RC, params, max_len=MIXED_LEN,
                     batch=MIXED_BATCH, quantize=True)
    assert s.prefill_plan_table != s.plan_table
    assert s.prefill_plan_table is not s.plan_table
    prompt = jax.random.randint(jax.random.PRNGKey(2),
                                (MIXED_BATCH, 6), 0, cfg.vocab)
    s.generate(prompt, n_new=4)
    s.reset()
    s.generate(prompt, n_new=3)
    # each phase's step traced exactly one program (None only if the
    # private jax jit-cache probe disappears)
    assert s.decode_executables in (1, None)
    assert s.prefill_executables in (1, None)
    # distinct programs: the phase steps are different jitted callables
    assert s._prefill_step is not s._step


def test_identical_phase_plans_share_one_program():
    """When no *projection* gate flips between phases the execution
    tables are aliased and both phases run the same compiled step —
    activation-score labels (phase-specific, never gated) must not
    force a redundant second program."""
    cfg = reduced(ARCHS[MIXED_ARCH])
    params = init(jax.random.PRNGKey(1), cfg)
    s = ServeSession(cfg, RC, params, max_len=64, batch=4, quantize=True)
    assert s.prefill_plan_table is s.plan_table
    assert s._prefill_step is s._step
    prompt = jax.random.randint(jax.random.PRNGKey(2), (4, 5), 0,
                                cfg.vocab)
    s.generate(prompt, n_new=4)
    assert s.decode_executables in (1, None)
    assert s.prefill_executables in (1, None)


def test_scheduler_switches_phase_tables_under_traffic():
    """The continuous-batching engine selects the prefill table on
    pure-prefill steps and flips back for decode, counting switches in
    telemetry; the total compiled variants stay at the number of
    distinct phase plans."""
    cfg = reduced(ARCHS[MIXED_ARCH])
    params = init(jax.random.PRNGKey(1), cfg)
    core = DecodeCore(cfg, RC, params, quantize=True,
                      plan_batch=MIXED_BATCH, plan_max_len=MIXED_LEN)
    assert core.prefill_plan_table != core.plan_table
    eng = ContinuousBatchingEngine(core, n_slots=4, max_len=32)
    prompts = np.arange(4 * 3, dtype=np.int32).reshape(4, 3) % cfg.vocab
    tel_all = eng.run([Request(rid=i, prompt=prompts[i],
                               max_new_tokens=4) for i in range(4)])
    tel = tel_all["aggregate"]["phase_gating"]
    assert tel["enabled"] is True
    assert tel["phase_switches"] >= 1        # prefill -> decode at least
    assert tel["phase_steps"]["prefill"] >= 1
    assert tel["phase_steps"]["decode"] >= 1
    assert (tel["phase_steps"]["prefill"] + tel["phase_steps"]["decode"]
            == eng.steps)
    # one compiled batch-step per distinct phase plan, nothing more
    assert core.batch_decode_executables in (2, None)


# --- widened-precision routes: INT4 / FP8 gated execution --------------------

@pytest.mark.parametrize("precision,routes", [
    ("int4", {"cim-int4-pallas", "int4-dequant-xla"}),
    ("fp8", {"cim-fp8-pallas", "fp8-dequant-xla"}),
])
def test_gated_vs_ungated_parity_lowbit(precision, routes):
    """Acceptance for the runtime What axis: a quantized session at
    INT4/FP8 routes at least one projection through the low-bit CiM
    Pallas path and at least one through the dequant-XLA path
    (verdict-dependent), matches the ungated program within kernel
    tolerance, and generates identical tokens — same low-bit weights,
    routing the only difference.  One executable per phase throughout."""
    cfg = reduced(ARCHS[MIXED_ARCH])
    params = init(jax.random.PRNGKey(1), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (8, 5), 0,
                                cfg.vocab)
    gated = ServeSession(cfg, RC, params, max_len=16, batch=8,
                         quantize=True, precision=precision)
    seen = {r["route"] for r in gated.route_report().values()}
    assert routes <= seen, seen

    ungated = ServeSession(cfg, RC, params, max_len=16, batch=8,
                           quantize=True, gated=False,
                           precision=precision)
    lg = np.asarray(gated.prefill(prompt), np.float32)
    lu = np.asarray(ungated.prefill(prompt), np.float32)
    np.testing.assert_allclose(lg, lu, rtol=5e-2, atol=5e-2)

    out_g = gated.generate(prompt[:, -1:], n_new=4)
    out_u = ungated.generate(prompt[:, -1:], n_new=4)
    np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out_u))
    assert gated.decode_executables in (1, None)
    assert gated.prefill_executables in (1, None)
