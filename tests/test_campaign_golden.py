"""Golden campaign-front fingerprint (ISSUE 9 satellite).

tests/golden/campaign_front.csv pins the byte-exact frontier CSV of a
fixed ~3k-point campaign grid — mistral-nemo-12b x {train_4k,
decode_32k}, all four prototypes, every supported precision
(INT8/INT4/FP8 — the widened What axis), all three cache levels, two
primitive-budget scales, both order modes, grouped per GEMM (the mode
whose groups span block boundaries, so the cross-chunk front merge is
load-bearing).  Any cost-model, sweep-backend, or reduction change that
moves a single front row fails here with a per-row diff — naming the
group, the golden row and the new one — instead of shipping a quiet
frontier drift.  Both batched backends are asserted against the same
file, and the chunked variant additionally asserts that at least two
engine chunks were exercised (the streaming acceptance criterion).

Intentional frontier changes regenerate the file:

    PYTHONPATH=src python tests/test_campaign_golden.py

and the diff lands in review along with the change that caused it.
"""
import csv
import os

from repro.core.campaign import FRONT_FIELDS, CampaignSpec, run_campaign
from repro.core.sweep import SweepEngine

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "campaign_front.csv")

# 20 GEMMs x 144 units = 2880 points
SPEC = CampaignSpec(
    workloads=(("mistral-nemo-12b", "train_4k"),
               ("mistral-nemo-12b", "decode_32k")),
    prototypes=("Analog-6T", "Analog-8T", "Digital-6T", "Digital-8T"),
    precisions=("int8", "int4", "fp8"),
    levels=("RF", "SMEM-A", "SMEM-B"),
    scales=(1.0, 4.0),
    serialize_modes=(True,),
    kn_thresholds=(4,),
    order_modes=("exact", "greedy"),
)
N_POINTS = 2880


def _front_rows(backend: str = "vectorized",
                engine: SweepEngine | None = None,
                block_points: int = 256) -> tuple[list[dict], dict]:
    """(formatted front rows, run stats) of the fixed golden grid."""
    engine = engine or SweepEngine(mesh=None)
    result = run_campaign(SPEC, engine=engine, backend=backend,
                          block_points=block_points, group_by="gemm")
    reader = csv.DictReader(result.csv_text().splitlines())
    return list(reader), result.stats


def _assert_matches_golden(backend: str,
                           engine: SweepEngine | None = None) -> None:
    with open(GOLDEN) as f:
        golden = list(csv.DictReader(f))
    got, stats = _front_rows(backend, engine)
    assert stats["n_points"] == N_POINTS, (
        f"golden grid enumerates {stats['n_points']} points, expected "
        f"{N_POINTS} — the spec or workload set changed; regenerate "
        f"the golden file (see module docstring)")
    assert len(golden) == len(got), (
        f"{backend} front has {len(got)} rows, golden has "
        f"{len(golden)} — regenerate tests/golden/campaign_front.csv "
        f"if intentional (see module docstring)")
    diffs = []
    for i, (want, have) in enumerate(zip(golden, got)):
        delta = [f"{k}: golden={want[k]!r} got={have[k]!r}"
                 for k in FRONT_FIELDS if want[k] != have[k]]
        if delta:
            diffs.append(f"  row {i} [{want['group']}/{want['label']}/"
                         f"{want['config']}]: " + "; ".join(delta))
    assert not diffs, (
        f"{backend} backend drifted from the golden campaign front on "
        f"{len(diffs)}/{len(golden)} rows:\n" + "\n".join(diffs[:25])
        + ("\n  ..." if len(diffs) > 25 else "")
        + "\nIf the drift is intentional, regenerate tests/golden/"
          "campaign_front.csv (see module docstring).")


def test_golden_front_vectorized():
    _assert_matches_golden("vectorized")


def test_golden_front_pallas():
    """Backend-parity gate: the Pallas sweep kernel reproduces the
    committed frontier byte for byte."""
    _assert_matches_golden("pallas")


def test_golden_front_chunked_engine():
    """The same frontier must come out of a chunk-streaming engine —
    and the grid must actually stream: >= 2 device chunks evaluated
    (the ISSUE 9 streaming acceptance criterion) with peak batch size
    bounded by chunk_rows."""
    engine = SweepEngine(mesh=None, chunk_rows=512)
    _assert_matches_golden("vectorized", engine)
    chunks = engine.cache_info()["chunks"]
    assert chunks["chunk_rows"] == 512
    assert chunks["evaluated"] >= 2, chunks
    assert chunks["rows"] + chunks["padded_rows"] \
        <= chunks["evaluated"] * 512


if __name__ == "__main__":
    engine = SweepEngine(mesh=None)
    result = run_campaign(SPEC, engine=engine, backend="vectorized",
                          block_points=256, group_by="gemm")
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    sha = result.write_csv(GOLDEN)
    print(f"wrote {len(result.front)} front rows to {GOLDEN} "
          f"(sha256 {sha[:16]})")
