"""Online adaptive planning: lattice, plan service, versioned tables,
and hot-swapped decode plans.

Covers the PR-7 contracts end to end on reduced CPU smoke configs:

* `BucketLattice` — snap-up bucketing (a bucket's representative shape
  dominates every point it serves), clamping beyond the grid, CLI-spec
  parsing, constructor validation;
* `PlanService` — cold-miss/warm-hit counters, `refresh_every`
  refreshes, verdict-flip detection with an injected `plan_fn`,
  background-thread drain;
* `KernelPlanTable` versioning — digest/equality stable across
  `from_decisions` orderings, `flips()` diffs, the
  KeyError-with-known-labels drift gate on swapped tables,
  `strip_model_prefix` edge cases;
* `DecodeCore.batch_step_for` — one compiled callable per distinct
  plan table in a bounded LRU (`max_plan_variants`);
* the engine — token-exact vs the frozen-plan engine when no verdict
  flips, and under a forced mid-run flip: hot-swap without retracing
  (`decode_executables == plan_variants == number of distinct plans`).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.core.plan_service import BucketLattice, PlanService
from repro.models import init
from repro.quant.plan_table import (KernelPlanTable, PlanEntry,
                                    strip_model_prefix)
from repro.serving import (ContinuousBatchingEngine, DecodeCore,
                           synthetic_requests)

RC = RunConfig(attn_impl="naive", remat=False)
MAX_LEN = 24
BLOCK = 4
N_SLOTS = 2


@pytest.fixture(scope="module")
def mamba():
    """Quantized gated ssm core at the engine planning shape."""
    cfg = reduced(ARCHS["mamba2-780m"])
    params = init(jax.random.PRNGKey(0), cfg)
    core = DecodeCore(cfg, RC, params, quantize=True,
                      plan_batch=N_SLOTS, plan_max_len=MAX_LEN)
    return cfg, params, core


def _engine(core, service=None, **kw):
    kw.setdefault("n_slots", N_SLOTS)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", BLOCK)
    return ContinuousBatchingEngine(core, plan_service=service, **kw)


def _requests(cfg, n=3):
    return synthetic_requests(cfg, n, seed=0, prompt_len=(3, 6),
                              new_tokens=(4, 8))


# --- BucketLattice ----------------------------------------------------------


def test_lattice_snaps_up_and_clamps():
    lat = BucketLattice((1, 2, 4), (8, 16, 24))
    assert lat.bucket_of(1, 0) == (1, 8)
    # max_pos snaps as a *length* (max_pos + 1): position 7 needs 8
    assert lat.bucket_of(1, 7) == (1, 8)
    assert lat.bucket_of(1, 8) == (1, 16)
    assert lat.bucket_of(3, 20) == (4, 24)
    # beyond the top edge: clamp, never KeyError
    assert lat.bucket_of(99, 999) == (4, 24)
    # degenerate inputs floor at 1
    assert lat.bucket_of(0, -3) == (1, 8)
    assert lat.n_buckets == 9


def test_lattice_bucket_dominates_served_point():
    """The representative shape is >= every point it serves (the plan
    must never be computed at a smaller GEMM than the live one)."""
    lat = BucketLattice.for_engine(4, 24)
    for n in range(1, 5):
        for pos in range(24):
            b, l = lat.bucket_of(n, pos)
            assert b >= n and l >= pos + 1


def test_lattice_for_engine_pow2_edges():
    lat = BucketLattice.for_engine(4, 24)
    assert lat.batch_edges == (1, 2, 4)
    assert lat.len_edges == (1, 2, 4, 8, 16, 24)
    # the top edge is always the true maximum, even when not a pow2
    assert BucketLattice.for_engine(3, 10).batch_edges == (1, 2, 3)


def test_lattice_parse_roundtrip_and_errors():
    lat = BucketLattice.parse("1,2,4:8,24")
    assert lat.batch_edges == (1, 2, 4)
    assert lat.len_edges == (8, 24)
    with pytest.raises(ValueError, match="bucket-edges spec"):
        BucketLattice.parse("1,2,4")          # no colon
    with pytest.raises(ValueError, match="bucket-edges spec"):
        BucketLattice.parse("1,x:8")          # non-integer


def test_lattice_validation():
    with pytest.raises(ValueError, match="must not be empty"):
        BucketLattice((), (8,))
    with pytest.raises(ValueError, match="must be positive"):
        BucketLattice((0, 2), (8,))
    with pytest.raises(ValueError, match="strictly ascending"):
        BucketLattice((1, 2), (8, 8))


# --- KernelPlanTable versioning ---------------------------------------------


def _decision(label, use_cim, what="baseline", where="PE"):
    """Minimal planner-Decision stand-in for from_decisions."""
    gemm = dataclasses.make_dataclass("G", ["label"])(label)
    return dataclasses.make_dataclass(
        "D", ["gemm", "use_cim", "what", "where"])(
            gemm, use_cim, what, where)


def test_digest_and_equality_stable_across_orderings():
    a = KernelPlanTable.from_decisions(
        [_decision("m Wq", True), _decision("m lm_head", False)],
        model_name="m")
    b = KernelPlanTable.from_decisions(
        [_decision("m lm_head", False), _decision("m Wq", True)],
        model_name="m")
    assert a == b
    assert hash(a) == hash(b)
    assert a.digest == b.digest
    assert len(a.digest) == 12
    # any verdict change is a new version
    assert a.with_flip("Wq").digest != a.digest


def test_flips_diffs_by_gate_and_one_sided_labels():
    a = KernelPlanTable.from_decisions(
        [_decision("Wq", True), _decision("Wk", False)])
    assert a.flips(a) == ()
    assert a.flips(a.with_flip("Wk")) == ("Wk",)
    # a label present in only one table counts as flipped
    wider = KernelPlanTable(entries=a.entries
                            + (("Wv", PlanEntry(use_cim=True)),))
    assert a.flips(wider) == ("Wv",)
    assert wider.flips(a) == ("Wv",)


def test_with_flip_keeps_drift_gate_on_swapped_tables():
    """The KeyError-with-known-labels contract survives a swap: the
    flipped variant must reject unknown labels exactly like the
    original (silent ungating on label drift is the failure mode)."""
    base = KernelPlanTable.from_decisions(
        [_decision("Wq", True), _decision("lm_head", False)])
    swapped = base.with_flip("lm_head")
    assert swapped.use_cim("lm_head") != base.use_cim("lm_head")
    assert swapped.use_cim("Wq") == base.use_cim("Wq")
    with pytest.raises(KeyError, match="known labels.*Wq"):
        swapped.use_cim("mlp-up")
    with pytest.raises(KeyError, match="unknown GEMM label"):
        base.with_flip("nope")


def test_strip_model_prefix_edges():
    assert strip_model_prefix("m Wq", "m") == "Wq"
    # empty model name: no prefix to strip
    assert strip_model_prefix("m Wq", "") == "m Wq"
    # label equal to the bare prefix (no trailing space): untouched
    assert strip_model_prefix("m", "m") == "m"
    # prefix-with-space but empty remainder strips to empty
    assert strip_model_prefix("m ", "m") == ""
    assert strip_model_prefix("other Wq", "m") == "other Wq"


# --- PlanService ------------------------------------------------------------


def _stub_plan_fn(flip_on_build=None):
    """Planner stub: one fixed verdict set, optionally toggling Wq from
    the `flip_on_build`-th build (0-indexed) of each shape onward."""
    builds = {}

    def plan_fn(shape):
        n = builds.get(shape.name, 0)
        builds[shape.name] = n + 1
        flip = flip_on_build is not None and n >= flip_on_build
        return [_decision("Wq", not flip), _decision("lm_head", False)]

    return plan_fn


def test_service_miss_then_hits(mamba):
    cfg, _, _ = mamba
    svc = PlanService(cfg, BucketLattice((2,), (24,)), background=False,
                      plan_fn=_stub_plan_fn())
    b1, t1 = svc.lookup(1, 3)
    b2, t2 = svc.lookup(2, 10)
    assert b1 == b2 == (2, 24)
    assert t1 is t2                      # memoized, not rebuilt
    tel = svc.telemetry()
    assert tel["lookups"] == 2
    rec = tel["buckets"]["b2xl24"]
    assert (rec["misses"], rec["hits"], rec["builds"]) == (1, 1, 1)
    assert rec["table_digest"] == t1.digest
    assert tel["hit_rate"] == 0.5
    assert tel["verdict_flips"] == 0


def test_service_refresh_and_flip_detection(mamba):
    cfg, _, _ = mamba
    svc = PlanService(cfg, BucketLattice((2,), (24,)), refresh_every=2,
                      background=False, plan_fn=_stub_plan_fn(flip_on_build=1))
    _, t0 = svc.lookup(1, 1)             # miss: build 0 (unflipped)
    _, t1 = svc.lookup(1, 1)             # hit 1
    _, t2 = svc.lookup(1, 1)             # hit 2 -> inline refresh: flip
    assert t1 == t0
    assert t2 != t0
    assert svc.verdict_flips == 1
    rec = svc.telemetry()["buckets"]["b2xl24"]
    assert rec["flips"] == 1
    assert rec["flipped_labels"] == ["Wq"]
    assert rec["builds"] == 2
    # the flipped table keeps being served (and re-confirmed) afterwards
    _, t3 = svc.lookup(1, 1)
    assert t3 == t2


def test_service_background_refresh_drains(mamba):
    cfg, _, _ = mamba
    svc = PlanService(cfg, BucketLattice((2,), (24,)), refresh_every=1,
                      background=True, plan_fn=_stub_plan_fn(flip_on_build=1))
    svc.lookup(1, 1)
    svc.lookup(1, 1)                     # schedules the background refresh
    svc.drain()
    assert svc.verdict_flips == 1
    _, t = svc.lookup(1, 1)
    assert t.use_cim("Wq") is False      # the flipped table landed


def test_service_rejects_negative_refresh(mamba):
    cfg, _, _ = mamba
    with pytest.raises(ValueError, match="refresh_every"):
        PlanService(cfg, BucketLattice((2,), (24,)), refresh_every=-1)


def test_service_default_planner_builds_real_table(mamba):
    """The un-stubbed service plans through the real batched sweep and
    produces a table equal to the core's frozen plan when the bucket
    matches the core's planning shape."""
    cfg, _, core = mamba
    svc = PlanService(cfg, BucketLattice((N_SLOTS,), (MAX_LEN,)),
                      background=False)
    _, table = svc.lookup(N_SLOTS, MAX_LEN - 1)
    assert table == core.plan_table
    assert table.digest == core.plan_table.digest


# --- DecodeCore bounded variant cache ---------------------------------------


def test_core_variant_cache_bounded_and_keyed_by_table(mamba):
    cfg, params, _ = mamba
    core = DecodeCore(cfg, RC, params, quantize=True,
                      plan_batch=N_SLOTS, plan_max_len=MAX_LEN,
                      max_plan_variants=2)
    base = core.plan_table
    fn0 = core.batch_step_for(base)
    assert core.batch_step_for(base) is fn0          # same table, same fn
    assert core.batch_step is fn0
    flipped = base.with_flip(base.labels[0])
    fn1 = core.batch_step_for(flipped)
    assert fn1 is not fn0
    assert core.plan_variants == 2
    assert core.plan_evictions == 0
    # a third distinct table evicts the LRU victim (base, the oldest)
    third = flipped.with_flip(base.labels[-1])
    core.batch_step_for(third)
    assert core.plan_variants == 2
    assert core.plan_evictions == 1
    # re-requesting the evicted table re-jits it and evicts the next
    # LRU victim — the bound holds
    core.batch_step_for(base)
    assert core.plan_variants == 2
    assert core.plan_evictions == 2


def test_core_rejects_nonpositive_variant_bound(mamba):
    cfg, params, _ = mamba
    with pytest.raises(ValueError, match="max_plan_variants"):
        DecodeCore(cfg, RC, params, quantize=True, plan_batch=N_SLOTS,
                   plan_max_len=MAX_LEN, max_plan_variants=0)


# --- engine integration ------------------------------------------------------


def test_engine_requires_gated_core_for_adaptive(mamba):
    cfg, params, _ = mamba
    ungated = DecodeCore(cfg, RC, params, quantize=False)
    svc = PlanService(cfg, BucketLattice((N_SLOTS,), (MAX_LEN,)),
                      background=False)
    with pytest.raises(ValueError, match="plan-gated core"):
        _engine(ungated, service=svc)


def test_adaptive_token_exact_when_no_flips(mamba):
    """Over a single-bucket lattice matching the frozen planning shape
    every lookup returns the frozen plan: the adaptive engine must be
    token-identical to the frozen-plan engine with zero swaps and one
    executable (the acceptance gate)."""
    cfg, _, core = mamba
    frozen = _engine(core)
    frozen.run(_requests(cfg), None)
    want = {r.rid: list(map(int, r.tokens)) for r in frozen.completed}

    svc = PlanService(cfg, BucketLattice((N_SLOTS,), (MAX_LEN,)),
                      background=False)
    eng = _engine(core, service=svc)
    t = eng.run(_requests(cfg), None)
    got = {r.rid: list(map(int, r.tokens)) for r in eng.completed}
    assert got == want
    ad = t["adaptive"]
    assert ad["plan_swaps"] == 0
    assert ad["service"]["verdict_flips"] == 0
    assert ad["active_plan_digest"] == core.plan_table.digest
    assert core.batch_decode_executables in (1, None)


def test_forced_flip_swaps_without_retrace(mamba):
    """A mid-run verdict flip hot-swaps the decode plan: the engine
    serves a second compiled variant and the compiled-program count
    equals the number of distinct plan tables (nothing retraced)."""
    cfg, params, _ = mamba
    core = DecodeCore(cfg, RC, params, quantize=True,
                      plan_batch=N_SLOTS, plan_max_len=MAX_LEN)
    base = core.plan_table

    def plan_fn(shape, _n=[0]):
        _n[0] += 1
        entries = base if _n[0] == 1 else base.with_flip("lm_head")
        return [_decision(lab, e.use_cim, e.what, e.where)
                for lab, e in entries.entries]

    svc = PlanService(cfg, BucketLattice((N_SLOTS,), (MAX_LEN,)),
                      refresh_every=3, background=False, plan_fn=plan_fn)
    eng = _engine(core, service=svc)
    t = eng.run(_requests(cfg, n=4), None)
    ad = t["adaptive"]
    assert t["aggregate"]["completed"] == 4
    assert ad["plan_swaps"] >= 1
    assert ad["service"]["verdict_flips"] >= 1
    assert ad["active_plan_digest"] == base.with_flip("lm_head").digest
    assert ad["swap_latency_s"]["count"] == ad["plan_swaps"]
    assert core.plan_variants == 2
    # the no-retrace gate, generalized: one lowered program per distinct
    # plan table served
    assert core.batch_decode_executables in (2, None)
