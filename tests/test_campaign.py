"""Unit tests for the design-space campaign subsystem (ISSUE 9
tentpole): grid spec enumeration, constraint contracts, streaming front
reduction in both grouping modes, block/chunk-boundary determinism, the
certification gate's bitwise re-evaluation, and the
`planner.summarize()` empty-input regression the certification path
exercises."""
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.core.campaign import (CIM_LEVELS, FRONT_FIELDS, OBJECTIVES,
                                 CampaignSpec, Constraint,
                                 area_proxy_bytes, build_config,
                                 certify_front, certify_point,
                                 run_campaign)
from repro.core.llm_workloads import gemms_of_model
from repro.core.memory import RF, configb_count, iso_area_primitive_count
from repro.core.pareto import dominates
from repro.core.planner import summarize
from repro.core.primitives import PRIMITIVES
from repro.core.sweep import SweepEngine

SMALL = CampaignSpec(
    workloads=(("mistral-nemo-12b", "train_4k"),),
    prototypes=("Analog-8T", "Digital-8T"),
    levels=CIM_LEVELS,
    scales=(1.0, 4.0),
    order_modes=("exact",),
)


# --- grid spec ---------------------------------------------------------------


def test_build_config_levels():
    prim = PRIMITIVES["Digital-8T"]
    rf = build_config("Digital-8T", "RF")
    assert rf.cim_level == "RF"
    assert rf.resolved_n_prims() == iso_area_primitive_count(RF, prim)
    a = build_config("Digital-8T", "SMEM-A")
    assert a.cim_level == "SMEM"
    assert a.resolved_n_prims() == iso_area_primitive_count(RF, prim)
    b = build_config("Digital-8T", "SMEM-B")
    assert b.resolved_n_prims() == configb_count(prim)
    # the scale axis multiplies the level's base budget
    assert build_config("Digital-8T", "RF", 4.0).resolved_n_prims() \
        == 4 * rf.resolved_n_prims()
    # and tiny scales clamp to one primitive, never zero
    assert build_config("Digital-8T", "RF", 1e-9).resolved_n_prims() == 1


def test_build_config_rejects_bad_axes():
    with pytest.raises(ValueError, match="unknown CiM prototype"):
        build_config("SRAM-9T", "RF")
    with pytest.raises(ValueError, match="unknown cache level"):
        build_config("Digital-8T", "DRAM")
    with pytest.raises(ValueError, match="scale"):
        build_config("Digital-8T", "RF", 0.0)


def test_area_proxy_scales_with_budget_and_overhead():
    cfg = build_config("Analog-8T", "RF", 2.0)
    prim = PRIMITIVES["Analog-8T"]
    assert area_proxy_bytes(cfg) == pytest.approx(
        cfg.resolved_n_prims() * prim.capacity_bytes
        * prim.area_overhead)
    # 8T analog pays more area than 6T digital at the same count
    a6 = build_config("Digital-8T", "RF")
    assert area_proxy_bytes(cfg) / cfg.resolved_n_prims() \
        > area_proxy_bytes(a6) / a6.resolved_n_prims()


def test_spec_validates_axes():
    with pytest.raises(ValueError, match="unknown arch"):
        CampaignSpec(workloads=(("not-a-model", "train_4k"),))
    with pytest.raises(ValueError, match="unknown shape"):
        CampaignSpec(workloads=(("mistral-nemo-12b", "train_9q"),))
    with pytest.raises(ValueError, match="at least one workload"):
        CampaignSpec(workloads=())
    with pytest.raises(ValueError):
        CampaignSpec(order_modes=("sideways",))
    with pytest.raises(ValueError, match="precision"):
        CampaignSpec(precisions=(0,))


def test_spec_lazy_enumeration_and_counts():
    n_gemms = len(gemms_of_model(ARCHS["mistral-nemo-12b"],
                                 SHAPES["train_4k"]))
    assert SMALL.n_points == n_gemms * SMALL.n_units
    it = SMALL.iter_points()
    assert not isinstance(it, (list, tuple))     # generator, not a grid
    seen = [p.index for p in it]
    assert seen == list(range(SMALL.n_points))   # canonical enumeration


def test_serialize_axis_is_rf_only():
    """serialize_primitives is a cost-model no-op at SMEM: crossing it
    there would put exact-duplicate points on every front, so non-RF
    levels take one serialize mode only."""
    spec = CampaignSpec(workloads=(("mistral-nemo-12b", "train_4k"),),
                        prototypes=("Digital-8T",),
                        serialize_modes=(True, False))
    units = spec.units()
    assert spec.n_units == len(units)
    rf = [u for u in units if u.level == "RF"]
    smem = [u for u in units if u.level != "RF"]
    assert {u.serialize for u in rf} == {True, False}
    assert {u.serialize for u in smem} == {True}


def test_spec_digest_tracks_axes():
    assert SMALL.digest() == SMALL.digest()
    other = CampaignSpec(workloads=SMALL.workloads,
                         prototypes=SMALL.prototypes,
                         levels=SMALL.levels, scales=(1.0, 8.0),
                         order_modes=SMALL.order_modes)
    assert other.digest() != SMALL.digest()


# --- constraint contracts ----------------------------------------------------


def test_constraint_parse_roundtrip():
    c = Constraint.parse("time_ns<=2e9")
    assert (c.metric, c.op, c.bound) == ("time_ns", "<=", 2e9)
    assert c.spec() == "time_ns<=2e+09"
    assert Constraint.parse(c.spec()) == c
    g = Constraint.parse("tops_per_w>=1.5")
    assert g.check(2.0) and not g.check(1.0)
    assert c.check(1e9) and not c.check(3e9)


def test_constraint_mask_vectorized():
    c = Constraint("area_bytes", "<=", 100.0)
    cols = {"area_bytes": np.asarray([50.0, 150.0, 100.0])}
    assert c.mask(cols).tolist() == [True, False, True]


def test_constraint_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown constraint metric"):
        Constraint.parse("joules<=1")
    with pytest.raises(ValueError, match="bad constraint"):
        Constraint.parse("time_ns=1e9")
    with pytest.raises(ValueError, match="bad constraint"):
        Constraint.parse("time_ns<=fast")
    with pytest.raises(ValueError, match="finite"):
        Constraint("time_ns", "<=", float("inf"))


# --- streaming front reduction -----------------------------------------------


def test_workload_mode_aggregates_count_weighted(engine):
    result = run_campaign(SMALL, engine=engine, block_points=64)
    assert result.front, "front must not be empty"
    row = result.front[0]
    assert set(FRONT_FIELDS) <= set(row)
    # recompute the row's objectives by hand: count-weighted sums over
    # the cell's GEMMs under the row's unit, in enumeration order
    unit = SMALL.units()[row["index"]]
    gemms = gemms_of_model(ARCHS["mistral-nemo-12b"], SHAPES["train_4k"])
    mets = engine.cim_metrics([(g, unit.cfg) for g in gemms],
                              unit.order_mode)
    energy = time = 0.0
    for g, m in zip(gemms, mets):
        energy += m.energy_pj * g.count
        time += m.time_ns * g.count
    assert row["energy_pj"] == energy
    assert row["time_ns"] == time
    assert row["area_bytes"] == unit.area_bytes
    assert row["n_gemms"] == len(gemms)


def test_front_rows_are_nondominated(engine):
    result = run_campaign(SMALL, engine=engine, group_by="gemm",
                          block_points=64)
    by_group: dict[tuple, list[dict]] = {}
    for r in result.front:
        by_group.setdefault((r["group"], r["label"], r["M"]),
                            []).append(r)
    assert len(by_group) == result.stats["n_groups"]
    for rows in by_group.values():
        pts = [[r[o] for o in OBJECTIVES] for r in rows]
        for i, a in enumerate(pts):
            for j, b in enumerate(pts):
                assert not (i != j and dominates(a, b)), (a, b)


def test_contracts_filter_before_reduction(engine):
    base = run_campaign(SMALL, engine=engine, block_points=64)
    cap = min(r["area_bytes"] for r in base.front)
    tight = (Constraint("area_bytes", "<=", cap),)
    got = run_campaign(SMALL, tight, engine=engine, block_points=64)
    assert all(r["area_bytes"] <= cap for r in got.front)
    assert got.stats["constraint_filtered"][tight[0].spec()] > 0
    # an unsatisfiable contract empties the front but still reports
    none = run_campaign(SMALL, (Constraint("area_bytes", "<=", 0.5),),
                        engine=engine, block_points=64)
    assert none.front == []
    assert none.stats["points_evaluated"] == SMALL.n_points


def test_block_and_chunk_boundaries_do_not_change_the_csv(engine):
    a = run_campaign(SMALL, engine=engine, block_points=SMALL.n_points,
                     group_by="gemm").csv_text()
    chunked = SweepEngine(mesh=None, chunk_rows=96)
    b = run_campaign(SMALL, engine=chunked, block_points=5,
                     group_by="gemm").csv_text()
    assert a == b
    assert chunked.cache_info()["chunks"]["evaluated"] >= 2


def test_run_campaign_rejects_bad_args(engine):
    with pytest.raises(ValueError, match="unknown group_by"):
        run_campaign(SMALL, engine=engine, group_by="prototype")
    with pytest.raises(ValueError, match="block_points"):
        run_campaign(SMALL, engine=engine, block_points=0)


def test_report_and_csv_structure(engine):
    result = run_campaign(SMALL, engine=engine, block_points=64)
    text = result.csv_text()
    assert text.splitlines()[0] == ",".join(FRONT_FIELDS)
    assert len(text.splitlines()) == len(result.front) + 1
    rep = result.report()
    assert rep["spec"]["digest"] == SMALL.digest()
    assert rep["stats"]["n_points"] == SMALL.n_points
    assert rep["front_rows"] == len(result.front)


# --- certification gate ------------------------------------------------------


def test_certify_point_reproduces_bitwise(engine):
    result = run_campaign(SMALL, engine=engine, block_points=64)
    champion = min(result.front, key=lambda r: r["energy_pj"])
    cert = certify_point(champion, engine=SweepEngine(mesh=None))
    assert cert["bitwise_ok"], cert
    assert cert["contracts_ok"] and cert["certified"]
    assert cert["recomputed"]["energy_pj"] \
        == champion["energy_pj"]          # exact float equality


def test_certify_point_catches_tampered_rows(engine):
    result = run_campaign(SMALL, engine=engine, block_points=64)
    row = dict(min(result.front, key=lambda r: r["energy_pj"]))
    row["energy_pj"] = row["energy_pj"] * 1.0000001
    cert = certify_point(row, engine=SweepEngine(mesh=None))
    assert not cert["bitwise_ok"]
    assert not cert["certified"]


def test_certify_front_champions(engine):
    contracts = (Constraint("area_bytes", "<=", 1e7),)
    result = run_campaign(SMALL, contracts, engine=engine,
                          block_points=64)
    cert = certify_front(result, objectives=("energy_pj", "time_ns"))
    assert cert["ok"]
    assert cert["groups_certified"] == 1
    assert all(p["contracts_ok"] for p in cert["points"])
    with pytest.raises(ValueError, match="certification objective"):
        certify_front(result, objectives=("joules",))


def test_certify_empty_filtered_subset_reports_not_zeros(engine):
    """With a contract no GEMM can meet per-GEMM, the planner summary
    over the contract-passing subset must surface summarize()'s
    empty-input ValueError — not an all-zero aggregate."""
    result = run_campaign(SMALL, engine=engine, block_points=64)
    champion = min(result.front, key=lambda r: r["energy_pj"])
    impossible = (Constraint("tops_per_w", ">=", 1e30),)
    cert = certify_point(champion, impossible,
                        engine=SweepEngine(mesh=None))
    assert not cert["contracts_ok"]
    pl = cert["planner"]
    assert pl["contract_passing_gemms"] == 0
    assert pl["filtered_summary"] is None
    assert "at least one Decision" in pl["filtered_summary_error"]


def test_summarize_raises_on_empty_decisions():
    """Regression (ISSUE 9 satellite): summarize([]) used to return an
    all-zero aggregate indistinguishable from a real no-CiM workload."""
    with pytest.raises(ValueError, match="at least one Decision"):
        summarize([])
