"""Planner-gated execution: KernelPlanTable routing, label coverage,
dtype discipline, and the end-to-end gated quantized decode.

The tentpole contract under test: What/When/Where verdicts become a
jit-static KernelPlanTable; every projection matmul in the model stack
routes through the single `models.layers.linear` entry point; with
quantize=True a ServeSession lowers CiM-gated labels to the INT8 Pallas
kernel and everything else to the standard path inside ONE compiled
decode executable, with logits parity against the ungated program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.configs.base import ShapeConfig
from repro.core.llm_workloads import gemms_of_model, projection_labels
from repro.core.planner import plan_workload
from repro.models import decode_step, forward, init, init_cache
from repro.models.layers import route_trace
from repro.quant import (KernelPlanTable, planned_linear,
                         quantize_model_params, quantize_weight)
from repro.serving import ServeSession

RC = RunConfig(remat=False, attn_impl="naive")

# one arch per family: the coverage sweep must see every projection kind
COVERAGE_ARCHS = ("mistral-nemo-12b", "qwen2-moe-a2.7b", "mamba2-780m",
                  "jamba-1.5-large-398b", "llama-3.2-vision-90b",
                  "musicgen-large")


def _plan_table(cfg, batch, max_len=32):
    shape = ShapeConfig("serve", max_len, batch, "decode")
    decisions = plan_workload(gemms_of_model(cfg, shape),
                              backend="vectorized")
    return KernelPlanTable.from_decisions(decisions, model_name=cfg.name)


# --- KernelPlanTable: static, hashable, loud on drift ------------------------

def test_plan_table_hashable_and_jit_static():
    table = _plan_table(reduced(ARCHS["mistral-nemo-12b"]), batch=2)
    assert hash(table) == hash(table)
    assert table == table
    assert table != table.ungated() or not any(
        e.use_cim for _, e in table.entries)
    # usable as a jit static argument (the engine closes over it instead,
    # but staticness is the load-bearing property either way)
    @jax.jit
    def f(x):
        return x + sum(e.use_cim for _, e in table.entries)
    f(jnp.zeros(()))


def test_plan_table_unknown_label_raises_with_known_list():
    table = _plan_table(reduced(ARCHS["mistral-nemo-12b"]), batch=2)
    assert table.use_cim("Wq") in (True, False)
    with pytest.raises(KeyError, match="mlp-gate"):
        table.use_cim("Wq_renamed")


def test_serve_session_use_cim_for_unknown_label_raises():
    cfg = reduced(ARCHS["mistral-nemo-12b"])
    s = ServeSession(cfg, RC, init(jax.random.PRNGKey(0), cfg),
                     max_len=16, batch=2)
    # full and short labels both resolve
    assert s.use_cim_for(f"{cfg.name} Wq") == s.use_cim_for("Wq")
    with pytest.raises(KeyError, match="known"):
        s.use_cim_for("no-such-gemm")


# --- planned_linear dtype discipline ----------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_cim", [True, False])
def test_planned_linear_respects_input_dtype(dtype, use_cim):
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 128),
                          jnp.float32) * 0.05
    q, s = quantize_weight(w)
    y = planned_linear(x, q, s, use_cim_path=use_cim, interpret=True)
    assert y.dtype == x.dtype, (y.dtype, x.dtype)
    ref = x.astype(jnp.float32) @ w
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                               rtol=0.1, atol=0.05)


def test_planned_linear_branch_parity_bf16():
    """Both branches in bfloat16 agree within kernel-numerics tolerance
    (the gated-decode parity gate in miniature)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 128),
                          jnp.float32) * 0.05
    q, s = quantize_weight(w)
    y_cim = planned_linear(x, q, s, use_cim_path=True, interpret=True)
    y_std = planned_linear(x, q, s, use_cim_path=False)
    np.testing.assert_allclose(
        np.asarray(y_cim, np.float32), np.asarray(y_std, np.float32),
        rtol=5e-2, atol=5e-2)


# --- label coverage: the model consumes exactly the planner's labels --------

@pytest.mark.parametrize("arch", COVERAGE_ARCHS)
def test_every_projection_label_has_exactly_one_linear_callsite(arch):
    """Every projection label emitted by gemms_of_model is consumed by
    the model stack through exactly one `linear(...)` call site (forward
    and decode share the projection helpers), and the model emits no
    label the planner doesn't know."""
    cfg = reduced(ARCHS[arch])
    b, l = 2, 8
    shape = ShapeConfig("serve", l, b, "decode")
    expected = projection_labels(cfg, shape)
    params = init(jax.random.PRNGKey(0), cfg)
    if cfg.family == "audio":
        tokens = jnp.zeros((b, l, cfg.audio.n_codebooks), jnp.int32)
        tok1 = jnp.zeros((b, 1, cfg.audio.n_codebooks), jnp.int32)
    else:
        tokens = jnp.zeros((b, l), jnp.int32)
        tok1 = jnp.zeros((b, 1), jnp.int32)
    kw = {}
    nimg = 0
    if cfg.family == "vlm":
        nimg = cfg.vision.n_image_tokens
        kw["image_embeds"] = jnp.zeros((b, nimg, cfg.d_model),
                                       jnp.bfloat16)
    cache = init_cache(cfg, RC, b, l, n_image_tokens=nimg)

    with route_trace() as records:
        jax.eval_shape(lambda p: forward(p, tokens, cfg, RC, **kw),
                       params)
        jax.eval_shape(
            lambda p, c: decode_step(p, c, tok1, jnp.int32(0), cfg, RC),
            params, cache)

    seen = {}
    for r in records:
        seen.setdefault(r["label"], set()).add(r["callsite"])
    assert set(seen) == expected, (
        f"label drift: model emits {sorted(set(seen) - expected)}, "
        f"misses {sorted(expected - set(seen))}")
    multi = {lab: sites for lab, sites in seen.items() if len(sites) > 1}
    assert not multi, f"labels with multiple linear call sites: {multi}"


# --- end-to-end gated decode ------------------------------------------------

def test_gated_decode_parity_and_single_executable():
    """Acceptance: with quantize=True the session routes at least one
    projection through the Pallas INT8 path and at least one through the
    standard path (verdict-dependent, mamba2 smoke at batch 8), matches
    the ungated program within kernel tolerance and the float program
    within INT8 tolerance, and compiles exactly one decode executable."""
    cfg = reduced(ARCHS["mamba2-780m"])
    params = init(jax.random.PRNGKey(1), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (8, 5), 0,
                                cfg.vocab)
    gated = ServeSession(cfg, RC, params, max_len=16, batch=8,
                         quantize=True)

    routes = {lab: r["route"] for lab, r in gated.route_report().items()}
    assert "cim-int8-pallas" in routes.values(), routes
    assert "int8-dequant-xla" in routes.values(), routes

    ungated = ServeSession(cfg, RC, params, max_len=16, batch=8,
                           quantize=True, gated=False)
    floats = ServeSession(cfg, RC, params, max_len=16, batch=8)

    lg = gated.prefill(prompt).astype(jnp.float32)
    lu = ungated.prefill(prompt).astype(jnp.float32)
    lf = floats.prefill(prompt).astype(jnp.float32)
    # routing parity: same INT8 weights, only the kernel differs
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lu),
                               rtol=5e-2, atol=5e-2)
    # quantization parity: gated INT8 vs float within INT8 tolerance
    scale = float(jnp.max(jnp.abs(lf))) + 1e-6
    assert float(jnp.max(jnp.abs(lg - lf))) < 0.1 * scale + 0.05

    out_g = gated.generate(prompt[:, -1:], n_new=4)
    out_u = ungated.generate(prompt[:, -1:], n_new=4)
    np.testing.assert_array_equal(np.asarray(out_g), np.asarray(out_u))
    # one lowered program: prefill + every decode token hit the same
    # executable (no per-token retrace)
    # (None only if the private jax jit-cache probe disappears)
    assert gated.decode_executables in (1, None)
    assert ungated.decode_executables in (1, None)


def test_gated_session_plan_built_before_jit():
    """quantize=True builds the plan eagerly; the table is frozen and the
    gated labels match the planner verdicts."""
    cfg = reduced(ARCHS["mamba2-780m"])
    s = ServeSession(cfg, RC, init(jax.random.PRNGKey(0), cfg),
                     max_len=16, batch=8, quantize=True)
    assert s._kernel_plan is not None       # no lazy build left pending
    assert s.plan_table is not None
    for lab, entry in s.plan_table.entries:
        assert entry.use_cim == s.use_cim_for(lab)


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "musicgen-large"])
def test_quantized_families_generate(arch):
    """Quantized+gated generation stays finite and deterministic across
    the structurally distinct families (MoE expert einsums, audio
    multi-codebook head)."""
    cfg = reduced(ARCHS[arch])
    params = init(jax.random.PRNGKey(0), cfg)
    if cfg.family == "audio":
        prompt = jax.random.randint(
            jax.random.PRNGKey(1), (2, 4, cfg.audio.n_codebooks), 0,
            cfg.vocab)
    else:
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0,
                                    cfg.vocab)
    s1 = ServeSession(cfg, RC, params, max_len=16, batch=2, quantize=True)
    s2 = ServeSession(cfg, RC, params, max_len=16, batch=2, quantize=True)
    o1 = s1.generate(prompt, n_new=4)
    o2 = s2.generate(prompt, n_new=4)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert s1.decode_executables in (1, None)


def test_quantize_model_params_structure():
    """Projection leaves become {"q", "scale"} with per-layer (stacked)
    scales; norms, biases, convs, router and embed stay float."""
    cfg = reduced(ARCHS["qwen2-moe-a2.7b"])
    params = init(jax.random.PRNGKey(0), cfg)
    qp = quantize_model_params(params)
    slot = qp["slots"][0]
    attn = slot["attn"]
    assert set(attn["wq"]) == {"q", "scale"}
    assert attn["wq"]["q"].dtype == jnp.int8
    # stacked leading layer axis survives with per-layer scales
    assert attn["wq"]["q"].shape[0] == attn["wq"]["scale"].shape[0]
    # MoE expert weights: (layers, E, d, f) with (layers, E, f) scales
    moe = slot["moe"]
    assert moe["w_gate"]["q"].ndim == 4
    assert moe["w_gate"]["scale"].shape == moe["w_gate"]["q"].shape[:2] \
        + (moe["w_gate"]["q"].shape[-1],)
    assert moe["router"].dtype == jnp.float32      # router stays float
    assert not isinstance(qp["embed"], dict)       # embedding gather
    assert not isinstance(slot["norm1"]["scale"], dict)
