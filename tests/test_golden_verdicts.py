"""Golden verdict fingerprint for the full planner grid.

tests/golden/planner_verdicts.csv pins the What/When/Where verdict of
every GEMM in the full llm_workloads set under the standard configs,
widened over every axis the planner decides on: all assigned archs x
(train_4k + decode_32k + the prefill/decode serving-phase workloads) x
every supported precision (INT8/INT4/FP8).  The standard configs span
all four Table-IV prototypes (analog and digital), so one row's verdict
already reflects the full What axis; precision and phase multiply the
row grid itself.  Any backend or cost-model change that silently drifts
a verdict fails here with a per-row diff — naming the GEMM, the golden
verdict and the new one — instead of shipping a quiet behavioural
change.  Both batched backends (vectorized XLA and the fused Pallas
kernel) are asserted against the same file, which also gates the
acceptance criterion that plan_workload(backend="pallas") matches the
vectorized backend on the full grid.

Intentional verdict changes regenerate the file:

    PYTHONPATH=src python tests/test_golden_verdicts.py

and the diff lands in review along with the change that caused it.
"""
import csv
import os

from repro.configs import ARCHS, SHAPES
from repro.core.campaign import parse_precision
from repro.core.llm_workloads import gemms_of_model, phase_gemms_of_model
from repro.core.planner import plan_workload

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "planner_verdicts.csv")
GRID_SHAPES = ("train_4k", "decode_32k")
# the serving-phase grid: the shapes DecodeCore plans per phase (prefill
# at M = seq_len, decode at M = batch) — phase verdicts are pinned here
# so a cost-model change that flips a phase gate shows up as a row diff.
PHASE_SEQ_LEN, PHASE_BATCH = 2048, 8
PRECISIONS = ("int8", "int4", "fp8")
FIELDS = ("arch", "shape", "precision", "label", "M", "N", "K",
          "best_energy", "best_throughput", "use_cim", "where")
N_GRID = 1338


def _grid():
    for arch, mc in ARCHS.items():
        workloads = [(sname, gemms_of_model(mc, SHAPES[sname]))
                     for sname in GRID_SHAPES]
        phases = phase_gemms_of_model(mc, PHASE_SEQ_LEN, PHASE_BATCH)
        workloads += [(f"phase-{ph}", gs) for ph, gs in phases.items()]
        for sname, gemms in workloads:
            for g in gemms:
                for tok in PRECISIONS:
                    bits, fp, _ = parse_precision(tok)
                    yield (arch, sname, tok,
                           g if (g.bits == bits and g.fp == fp)
                           else g.scaled(bits=bits, fp=fp))


def _verdict_rows(backend: str = "vectorized", plan=None) -> list[dict]:
    """Verdict rows of the full grid, in golden-CSV field conventions.

    `plan` overrides how the decisions are produced (gemms -> decisions)
    — the distributed parity worker routes through its multi-host engine
    here, so the formatting the bitwise comparison depends on has
    exactly one definition."""
    entries = list(_grid())
    gemms = [g for _, _, _, g in entries]
    decisions = (plan(gemms) if plan is not None
                 else plan_workload(gemms, backend=backend))
    return [{"arch": arch, "shape": sname, "precision": prec,
             "label": g.label,
             "M": str(g.M), "N": str(g.N), "K": str(g.K),
             "best_energy": d.best_energy,
             "best_throughput": d.best_throughput,
             "use_cim": str(int(d.use_cim)), "where": d.where}
            for (arch, sname, prec, g), d in zip(entries, decisions)]


def _assert_matches_golden(backend: str) -> None:
    with open(GOLDEN) as f:
        golden = list(csv.DictReader(f))
    got = _verdict_rows(backend)
    assert len(golden) == N_GRID, (
        f"golden file has {len(golden)} rows, expected {N_GRID} — "
        f"regenerate it (see module docstring)")
    assert len(got) == N_GRID, (
        f"workload grid produced {len(got)} GEMMs, expected {N_GRID} — "
        f"llm_workloads changed; regenerate the golden file")
    diffs = []
    for i, (want, have) in enumerate(zip(golden, got)):
        delta = [f"{k}: golden={want[k]!r} got={have[k]!r}"
                 for k in FIELDS if want[k] != have[k]]
        if delta:
            diffs.append(f"  row {i} [{want['arch']}/{want['shape']}/"
                         f"{want['precision']}/{want['label']}]: "
                         + "; ".join(delta))
    assert not diffs, (
        f"{backend} backend drifted from the golden verdicts on "
        f"{len(diffs)}/{N_GRID} rows:\n" + "\n".join(diffs[:25])
        + ("\n  ..." if len(diffs) > 25 else "")
        + "\nIf the drift is intentional, regenerate tests/golden/"
          "planner_verdicts.csv (see module docstring).")


def test_golden_verdicts_vectorized():
    _assert_matches_golden("vectorized")


def test_golden_verdicts_pallas():
    """The full-grid pallas gate: identical What/When/Where verdicts to
    the committed fingerprint (and therefore to the vectorized backend)
    on every (arch, shape/phase, precision) row of the widened grid."""
    _assert_matches_golden("pallas")


if __name__ == "__main__":
    rows = _verdict_rows("vectorized")
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    with open(GOLDEN, "w", newline="") as f:
        writer = csv.DictWriter(f, FIELDS)
        writer.writeheader()
        writer.writerows(rows)
    print(f"wrote {len(rows)} verdict rows to {GOLDEN}")
