"""Per-kernel shape/dtype sweeps, interpret=True vs the jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core.tpu_adapter import choose_blocks

KEY = jax.random.PRNGKey(0)


def _assert_close(got, want, tol=2e-3):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


# --- int8 weight-stationary GEMM ------------------------------------------

@pytest.mark.parametrize("shape", [(32, 64, 128), (64, 128, 64),
                                   (128, 256, 256), (8, 128, 512)])
@pytest.mark.parametrize("dataflow", ["os", "ws"])
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_int8_gemm_sweep(shape, dataflow, xdtype):
    M, N, K = shape
    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (M, K), xdtype)
    w_q = jax.random.randint(k2, (K, N), -127, 127, jnp.int8)
    ws = jax.random.uniform(k3, (N,), jnp.float32, 0.01, 0.1)
    got = ops.int8_matmul(x, w_q, ws, dataflow=dataflow, block_m=8,
                          block_n=64, block_k=64, interpret=True)
    want = ref.int8_gemm_ref(x, w_q, ws)
    tol = 2e-2 if xdtype == jnp.bfloat16 else 2e-3
    _assert_close(got, want, tol)


def test_int8_gemm_adapter_blocks():
    M, N, K = 256, 512, 1024
    bm, bn, bk = choose_blocks(M, N, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    # weight tile must fit half the VMEM budget
    assert bk * bn <= 4 * 1024 * 1024
    x = jax.random.normal(KEY, (M, K), jnp.float32)
    w_q = jax.random.randint(KEY, (K, N), -127, 127, jnp.int8)
    ws = jnp.full((N,), 0.05, jnp.float32)
    got = ops.int8_matmul(x, w_q, ws, interpret=True)
    _assert_close(got, ref.int8_gemm_ref(x, w_q, ws))


# --- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("s,h,kv,d", [(128, 4, 4, 64), (256, 4, 2, 32),
                                      (256, 8, 1, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(s, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, s, h, d), dtype)
    k = jax.random.normal(ks[1], (2, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (2, s, kv, d), dtype)
    got = ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                              interpret=True)
    ke = jnp.repeat(k, h // kv, 2)
    ve = jnp.repeat(v, h // kv, 2)
    want = ref.flash_attention_ref(q, ke, ve)
    _assert_close(got, want, 3e-2 if dtype == jnp.bfloat16 else 2e-3)


def test_flash_attention_sliding_window():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 4, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 4, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, window=64, block_q=64,
                              block_kv=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=64)
    _assert_close(got, want)


def test_flash_matches_model_flash_jnp():
    # the model's chunked-jnp attention and the Pallas kernel must agree
    from repro.models.attention import flash_jnp
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (2, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (2, 256, 2, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, block_q=64, block_kv=64,
                            interpret=True)
    b = flash_jnp(q, k, v, chunk=64)
    _assert_close(a, b)


# --- decode attention -----------------------------------------------------------

@pytest.mark.parametrize("S,length", [(256, 256), (512, 300), (1024, 7)])
def test_decode_attention_sweep(S, length):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 8, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (2, S, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, S, 2, 64), jnp.float32)
    got = ops.decode_attention(q, kc, vc, jnp.int32(length),
                               block_kv=128, interpret=True)
    want = ref.decode_attention_ref(q[:, 0], jnp.repeat(kc, 4, 2),
                                    jnp.repeat(vc, 4, 2), length)
    _assert_close(got[:, 0], want)


def test_decode_matches_model_decode_attend():
    from repro.models.attention import decode_attend
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32), jnp.float32)
    kc = jax.random.normal(ks[1], (2, 256, 4, 32), jnp.float32)
    vc = jax.random.normal(ks[2], (2, 256, 4, 32), jnp.float32)
    a = ops.decode_attention(q, kc, vc, jnp.int32(100), block_kv=64,
                             interpret=True)
    b = decode_attend(q, kc, vc, jnp.full((2,), 100, jnp.int32))
    _assert_close(a, b)
