"""Continuous batching: slot scheduling, paged KV, and parity with the
legacy fixed-batch session.

The engine contract under test: any ragged request stream — join at
full occupancy, evict-on-EOS mid-scan, single-lane traffic — runs
through ONE compiled masked decode step (`decode_executables == 1`)
and produces, per request, exactly the tokens the legacy
`ServeSession(batch=1)` produces for that request alone.  mamba2-780m
is the mixed-verdict gated case (ssm-BCdt on CiM, the rest standard);
mistral-nemo-12b exercises the paged-KV gather/scatter across block
boundaries.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.models import init
from repro.serving import (BlockAllocator, ContinuousBatchingEngine,
                           DecodeCore, Request, ServeSession,
                           synthetic_requests)

RC = RunConfig(remat=False, attn_impl="naive")
MAX_LEN = 24
BLOCK = 4          # small so smoke prompts cross block edges


def _core(arch: str, quantize: bool):
    cfg = reduced(ARCHS[arch])
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params, DecodeCore(cfg, RC, params, quantize=quantize,
                                   plan_batch=4, plan_max_len=MAX_LEN)


@pytest.fixture(scope="module")
def mamba():
    """Quantized gated ssm core (the mixed-verdict arch)."""
    return _core("mamba2-780m", quantize=True)


@pytest.fixture(scope="module")
def attn():
    """Quantized attention core (paged KV path)."""
    return _core("mistral-nemo-12b", quantize=True)


def _engine(core, n_slots, **kw):
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", BLOCK)
    return ContinuousBatchingEngine(core, n_slots=n_slots, **kw)


def _legacy_tokens(cfg, params, prompt, n_new, quantize=True):
    s = ServeSession(cfg, RC, params, max_len=MAX_LEN, batch=1,
                     quantize=quantize)
    out = s.generate(np.asarray(prompt)[None], n_new=n_new)
    return np.asarray(jax.device_get(out)).reshape(-1)


# --- BlockAllocator ----------------------------------------------------------

def test_allocator_all_or_nothing_and_reuse():
    a = BlockAllocator(4)
    first = a.alloc(3)
    assert len(first) == 3 and a.free_blocks == 1
    assert a.alloc(2) is None          # exhaustion: nothing is handed out
    assert a.free_blocks == 1
    a.free(first)
    again = a.alloc(4)
    assert a.free_blocks == 0
    assert set(first) <= set(again)      # freed ids are reused, not grown
    assert set(again) == set(range(4))
    assert a.peak_in_use == 4


def test_allocator_rejects_double_free():
    """A double-free (in a later call or within one call) raises and
    leaves the free list untouched — a silently re-listed id would be
    handed to two slots."""
    a = BlockAllocator(4)
    blocks = a.alloc(2)
    a.free(blocks)
    with pytest.raises(ValueError, match="double-free"):
        a.free(blocks)                       # already back in the pool
    assert a.free_blocks == 4                # state untouched by the raise
    fresh = a.alloc(1)
    with pytest.raises(ValueError, match="double-free"):
        a.free(fresh + fresh)                # duplicate within one call
    assert a.in_use == 1                     # still held: nothing mutated
    a.free(fresh)                            # the valid free still works
    assert a.free_blocks == 4
    assert a.peak_in_use == 2                # unchanged by the bad calls


def test_allocator_rejects_foreign_ids():
    """Ids the pool never issued (negative or >= n_blocks) raise; a
    mixed batch of valid+foreign ids mutates nothing."""
    a = BlockAllocator(4)
    held = a.alloc(2)
    for bad in ([-1], [4], [99]):
        with pytest.raises(ValueError, match=r"outside pool \[0, 4\)"):
            a.free(bad)
    with pytest.raises(ValueError):
        a.free([held[0], 7])                 # valid id rides along: still atomic
    assert a.in_use == 2                     # the valid id was NOT freed
    a.free(held)
    assert a.free_blocks == 4
    assert a.peak_in_use == 2


def test_pool_exhaustion_defers_admission(mamba):
    """A KV-less arch can't exercise pool pressure, so force it via a
    tiny allocator on the attention-free engine path is moot — instead
    check the admission math directly on the scheduler."""
    cfg, params, core = mamba
    eng = _engine(core, n_slots=2)
    assert eng.allocator.n_blocks == 0          # ssm: no KV pool needed
    r = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                max_new_tokens=MAX_LEN)         # horizon > max_len
    with pytest.raises(ValueError, match="exceeds engine max_len"):
        eng.submit(r)


def test_pool_exhaustion_blocks_admission_attn(attn):
    cfg, params, core = attn
    # pool holds exactly one request's horizon: the second must queue
    # until the first evicts and frees its blocks
    blocks_per_req = math.ceil((4 + 4) / BLOCK)
    eng = _engine(core, n_slots=2, n_kv_blocks=blocks_per_req)
    reqs = synthetic_requests(cfg, 2, seed=3, prompt_len=(4, 4),
                              new_tokens=(4, 4))
    eng.run(reqs, None)
    assert len(eng.completed) == 2
    a, b = sorted(eng.completed, key=lambda r: r.t_admit)
    assert b.t_admit >= a.t_done          # serialized by pool pressure
    assert eng.allocator.peak_in_use == blocks_per_req
    assert eng.allocator.free_blocks == blocks_per_req   # all reclaimed


# --- slot scheduling ---------------------------------------------------------

def test_join_at_full_occupancy(mamba):
    """Three requests into two slots: the third queues, then takes the
    first freed slot mid-run."""
    cfg, params, core = mamba
    eng = _engine(core, n_slots=2)
    reqs = synthetic_requests(cfg, 3, seed=1, prompt_len=(3, 5),
                              new_tokens=(4, 8))
    t = eng.run(reqs, None)
    assert t["aggregate"]["completed"] == 3
    assert t["aggregate"]["queue_depth_max"] >= 1
    last = max(eng.completed, key=lambda r: r.t_admit)
    first_done = min(r.t_done for r in eng.completed)
    assert last.t_admit > last.t_submit        # it waited in the queue
    assert last.t_admit >= first_done          # ...until a slot freed
    # and the queued request still matches its solo legacy run
    want = _legacy_tokens(cfg, params, last.prompt, last.max_new_tokens)
    assert np.array_equal(np.asarray(last.tokens), want)


def test_evict_on_eos_mid_scan(mamba):
    """Learn the greedy token stream, re-run with one of its tokens as
    EOS: the request must finish early with done_reason='eos' while the
    other slot keeps decoding to max_tokens."""
    cfg, params, core = mamba
    probe = _engine(core, n_slots=1)
    probe.run(synthetic_requests(cfg, 1, seed=2, prompt_len=(4, 4),
                                 new_tokens=(8, 8)), None)
    stream = [int(t) for t in probe.completed[0].tokens]
    eos = stream[2]                     # third token => early stop
    prompt = probe.completed[0].prompt

    eng = _engine(core, n_slots=2)
    eng.submit(Request(rid="eos", prompt=prompt, max_new_tokens=8,
                       eos_id=eos))
    eng.submit(Request(rid="full", prompt=prompt, max_new_tokens=8))
    eng.drain()
    by_rid = {r.rid: r for r in eng.completed}
    assert by_rid["eos"].done_reason == "eos"
    assert len(by_rid["eos"].tokens) == 3      # stops AT the eos token
    assert [int(t) for t in by_rid["eos"].tokens] == stream[:3]
    assert by_rid["full"].done_reason == "max_tokens"
    assert len(by_rid["full"].tokens) == 8
    assert by_rid["full"].t_done > by_rid["eos"].t_done


def test_single_request_batch_matches_legacy(mamba):
    cfg, params, core = mamba
    eng = _engine(core, n_slots=1)
    reqs = synthetic_requests(cfg, 1, seed=5, prompt_len=(6, 6),
                              new_tokens=(10, 10))
    eng.run(reqs, None)
    r = eng.completed[0]
    want = _legacy_tokens(cfg, params, r.prompt, r.max_new_tokens)
    assert np.array_equal(np.asarray(r.tokens), want)


# --- parity + no-retrace -----------------------------------------------------

@pytest.mark.parametrize("arch_fixture", ["mamba", "attn"])
def test_continuous_matches_fixed_batch(arch_fixture, request):
    """Token + first-logits parity of the continuous engine against the
    legacy per-request session, through slot churn.  Prompts are long
    enough that the attention arch's paged KV crosses block boundaries
    (prompt + output > BLOCK)."""
    cfg, params, core = request.getfixturevalue(arch_fixture)
    eng = _engine(core, n_slots=3, record_logits=True)
    reqs = synthetic_requests(cfg, 5, seed=7, prompt_len=(4, 9),
                              new_tokens=(5, 12))
    t = eng.run(reqs, None)
    assert t["aggregate"]["completed"] == 5
    legacy = ServeSession(cfg, RC, params, max_len=MAX_LEN, batch=1,
                          quantize=True)
    for r in eng.completed:
        prompt = np.asarray(r.prompt)[None]
        legacy.reset()
        ref_logits = legacy.prefill(prompt).astype(jnp.float32)
        legacy.reset()
        want = np.asarray(jax.device_get(
            legacy.generate(prompt, n_new=r.max_new_tokens))).reshape(-1)
        assert np.array_equal(np.asarray(r.tokens), want), r.rid
        np.testing.assert_allclose(
            np.asarray(r.first_logits),
            np.asarray(jax.device_get(ref_logits[0, -1])),
            rtol=0, atol=1e-5)
    # no-retrace: a second pass of different ragged traffic at the same
    # slot count must reuse the executable (the module-shared core has
    # one program per distinct n_slots used by earlier tests, so the
    # meaningful gate here is "no growth", not an absolute count)
    n_before = eng.decode_executables
    eng2 = _engine(core, n_slots=3)
    eng2.run(synthetic_requests(cfg, 2, seed=8, prompt_len=(3, 7),
                                new_tokens=(3, 7)), None)
    if n_before is not None:
        assert eng2.decode_executables == n_before


def test_decode_executables_one_after_churn():
    """Fresh gated core, fixed slot count, back-to-back runs with
    different ragged traffic: exactly one compiled masked step — the
    bench's absolute no-retrace gate."""
    cfg, params, core = _core("mamba2-780m", quantize=True)
    for n_req, seed in ((3, 11), (1, 12)):
        eng = _engine(core, n_slots=2)
        eng.run(synthetic_requests(cfg, n_req, seed=seed,
                                   prompt_len=(3, 6),
                                   new_tokens=(3, 6)), None)
        assert len(eng.completed) == n_req
        assert eng.decode_executables in (1, None)


def test_vlm_rejected():
    cfg = reduced(ARCHS["llama-3.2-vision-90b"])
    params = init(jax.random.PRNGKey(0), cfg)
    core = DecodeCore(cfg, RC, params, quantize=False)
    with pytest.raises(NotImplementedError, match="image embeddings"):
        ContinuousBatchingEngine(core, n_slots=2, max_len=MAX_LEN)


def test_telemetry_handles_request_without_first_token(mamba):
    """A request can complete without ever generating a token (evicted
    before its first decode): t_first is None.  telemetry() must emit
    None latency fields for it and exclude it from the TTFT percentiles
    instead of raising (the regression: `None - float` TypeError)."""
    cfg, params, core = mamba
    eng = _engine(core, n_slots=2)
    eng.run(synthetic_requests(cfg, 2, seed=3, prompt_len=(3, 5),
                               new_tokens=(3, 5)), None)
    ghost = Request(rid="ghost", prompt=np.arange(3, dtype=np.int32),
                    max_new_tokens=4)
    ghost.state, ghost.done_reason = "done", "max_tokens"
    ghost.t_submit, ghost.t_done = 0.0, 1.0   # admitted/decoded: never
    eng.completed.append(ghost)
    t = eng.telemetry()                        # must not raise
    by_rid = {r["rid"]: r for r in t["requests"]}
    g = by_rid["ghost"]
    assert g["ttft_s"] is None
    assert g["queue_wait_s"] is None
    assert g["decode_tokens_per_s"] is None
    # percentiles computed over the two real requests only
    real_ttfts = [r["ttft_s"] for r in t["requests"] if r["rid"] != "ghost"]
    assert all(x is not None for x in real_ttfts)
    agg = t["aggregate"]
    assert agg["completed"] == 3
    assert min(real_ttfts) <= agg["ttft_p50_s"] <= max(real_ttfts)
    assert agg["ttft_p95_s"] is not None
