"""End-to-end integration: training convergence, crash/auto-resume
determinism, serving generation, planner-gated quantized execution."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.data import DataConfig
from repro.models import init
from repro.quant import planned_linear, quantize_weight
from repro.serving import ServeSession
from repro.train import train
from repro.train.fault_tolerance import FailureInjector

RC = RunConfig(remat=False, attn_impl="naive", learning_rate=1e-3,
               warmup_steps=5)


@pytest.mark.slow
def test_tiny_lm_learns():
    cfg = reduced(ARCHS["qwen2-7b"])
    dc = DataConfig(seed=0, vocab=cfg.vocab, seq_len=64, global_batch=8)
    res = train(cfg, RC, dc, n_steps=30, seed=0)
    assert res.losses[-1] < res.losses[0] - 0.3


@pytest.mark.slow
def test_crash_resume_is_deterministic():
    cfg = reduced(ARCHS["qwen2-7b"])
    dc = DataConfig(seed=0, vocab=cfg.vocab, seq_len=32, global_batch=4)
    with tempfile.TemporaryDirectory() as d:
        inj = FailureInjector(fail_at_steps=(12,))
        with pytest.raises(RuntimeError):
            train(cfg, RC, dc, n_steps=20, seed=0, ckpt_dir=d,
                  ckpt_every=5, injector=inj)
        resumed = train(cfg, RC, dc, n_steps=20, seed=0, ckpt_dir=d,
                        ckpt_every=5)
        assert resumed.resumed_from == 10
        full = train(cfg, RC, dc, n_steps=20, seed=0)
        np.testing.assert_allclose(resumed.losses[-3:], full.losses[-3:],
                                   rtol=3e-4, atol=3e-4)


@pytest.mark.slow
def test_microbatched_grads_match_full_batch():
    from repro.train import make_train_step
    from repro.optim import make_optimizer
    cfg = reduced(ARCHS["minitron-4b"])
    params = init(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw")[0](params)
    dc = DataConfig(seed=3, vocab=cfg.vocab, seq_len=32, global_batch=8)
    from repro.data import batch_at_step
    batch = batch_at_step(dc, 0)
    rc1 = RC
    rc4 = RunConfig(remat=False, attn_impl="naive", learning_rate=1e-3,
                    warmup_steps=5, microbatches=4)
    _, _, m1 = jax.jit(make_train_step(cfg, rc1))(params, opt, batch,
                                                  jnp.int32(0))
    _, _, m4 = jax.jit(make_train_step(cfg, rc4))(params, opt, batch,
                                                  jnp.int32(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=5e-3)


def test_serving_generates_and_is_deterministic():
    cfg = reduced(ARCHS["mistral-nemo-12b"])
    params = init(jax.random.PRNGKey(1), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0,
                                cfg.vocab)
    s1 = ServeSession(cfg, RC, params, max_len=32, batch=2)
    out1 = s1.generate(prompt, n_new=8, temperature=0.0)
    s2 = ServeSession(cfg, RC, params, max_len=32, batch=2)
    out2 = s2.generate(prompt, n_new=8, temperature=0.0)
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_planner_gated_linear_matches_dense():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 128),
                          jnp.float32) * 0.05
    q, s = quantize_weight(w)
    y_cim = planned_linear(x, q, s, use_cim_path=True, interpret=True)
    y_std = planned_linear(x, q, s, use_cim_path=False)
    np.testing.assert_allclose(np.asarray(y_cim), np.asarray(y_std),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_what_when_where_llm_decisions():
    """Paper Table V embodied: train-shape FFN GEMM -> CiM; decode GEMV
    at small batch -> baseline."""
    from repro.core import GEMM, decide
    ffn = GEMM(4096, 1408, 2048, label="train expert GEMM")
    gemv = GEMM(1, 18944, 3584, label="bs-1 decode GEMM")
    d_ffn = decide(ffn)
    d_gemv = decide(gemv)
    assert d_ffn.best_energy != "baseline"
    assert not d_gemv.use_cim
