"""Calibration tests: the cost model must reproduce the paper's reported
numbers (within tolerance) — these lock the reproduction's fidelity.

Anchors (paper section in brackets):
  [Fig 10a / 11a] Digital-6T@RF saturating throughput = 455 GFLOPS.
  [Fig 13a]       Analog-6T@RF saturating throughput ~= 57 GFLOPS.
  [Fig 11a]       BERT-Large layers > 1.67 TOPS/W at Digital-6T@RF.
  [Fig 11a]       M=1 GPT-J decode / DLRM ~= 0.03 TOPS/W, ~= 31 GFLOPS.
  [Fig 12a]       BERT energy-efficiency gain vs baseline ~= 3x.
  [Fig 10a]       K=256,N=32: max 455 GFLOPS with utilization 2/3.
  [Fig 13a]       large square GEMMs: A-2 ~= 620 fJ/MAC, A-1 ~= 700 fJ/MAC.
  [Fig 11b]       Digital-6T@SMEM configB ~= 10x RF throughput, slightly
                  higher TOPS/W (~ +0.25).
  [§VI]           headline: up to ~3.4x energy efficiency vs baseline.
"""
import pytest

from repro.core import (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T, GEMM,
                        CiMSystemConfig, configb_count, evaluate,
                        evaluate_baseline, iso_area_primitive_count, RF)

D6_RF = CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF")
A6_RF = CiMSystemConfig(prim=ANALOG_6T, cim_level="RF")
A8_RF = CiMSystemConfig(prim=ANALOG_8T, cim_level="RF")

BERT = GEMM(512, 1024, 1024)
GEMV = GEMM(1, 4096, 4096)


def test_iso_area_counts():
    # paper: 3 Digital-6T primitives fit at RF under iso-area
    assert iso_area_primitive_count(RF, DIGITAL_6T) == 3
    # paper configB: 16x the RF count at SMEM
    assert configb_count(DIGITAL_6T) == 48


def test_d6t_throughput_saturation_455():
    m = evaluate(GEMM(4096, 4096, 4096), D6_RF)
    assert m.gflops == pytest.approx(455.0, rel=0.05)


def test_a6t_throughput_saturation_57():
    m = evaluate(GEMM(8192, 8192, 8192), A6_RF)
    assert m.gflops == pytest.approx(57.0, rel=0.05)


def test_low_parallelism_primitives_are_slow():
    # paper Fig 13: A-2 and D-2 excluded for extremely low performance
    a2 = evaluate(GEMM(2048, 2048, 2048), A8_RF)
    d2 = evaluate(GEMM(2048, 2048, 2048),
                  CiMSystemConfig(prim=DIGITAL_8T, cim_level="RF"))
    assert a2.gflops < 10.0
    assert d2.gflops < 5.0


def test_bert_tops_per_w_band():
    m = evaluate(BERT, D6_RF)
    assert 1.6 < m.tops_per_w < 2.1     # paper: 1.67 .. 1.97


def test_gemv_decode_pathology():
    m = evaluate(GEMV, D6_RF)
    assert m.tops_per_w == pytest.approx(0.03, abs=0.01)
    assert m.gflops == pytest.approx(31.0, rel=0.15)


def test_gemv_baseline_beats_cim_throughput():
    cim = evaluate(GEMV, D6_RF)
    base = evaluate_baseline(GEMV)
    assert base.gflops > 1.5 * cim.gflops  # paper §VI-C takeaway


def test_bert_vs_baseline_energy_ratio_about_3x():
    cim = evaluate(BERT, D6_RF)
    base = evaluate_baseline(BERT)
    assert 2.3 < cim.tops_per_w / base.tops_per_w < 3.8


def test_k256_n32_sweet_spot():
    m = evaluate(GEMM(512, 32, 256), D6_RF)
    assert m.gflops == pytest.approx(455.0, rel=0.02)
    assert m.utilization == pytest.approx(2 / 3, abs=0.01)


def test_large_square_fj_per_mac():
    a2 = evaluate(GEMM(8192, 8192, 8192), A8_RF)
    a1 = evaluate(GEMM(8192, 8192, 8192), A6_RF)
    # fJ per MAC = 2 * fJ per op
    assert 2 * a2.fj_per_op == pytest.approx(620.0, rel=0.20)
    assert 2 * a1.fj_per_op == pytest.approx(700.0, rel=0.20)


def test_smem_configb_beats_rf():
    rf = evaluate(BERT, D6_RF)
    smem_b = evaluate(BERT, CiMSystemConfig(
        prim=DIGITAL_6T, cim_level="SMEM", n_prims=configb_count(DIGITAL_6T)))
    assert smem_b.gflops > 5 * rf.gflops        # "approximately tenfold"
    assert smem_b.tops_per_w > rf.tops_per_w    # "slightly higher"
    assert smem_b.tops_per_w - rf.tops_per_w < 0.8


def test_energy_plateau_with_m():
    # paper Fig 10a: TOPS/W rises with M to a sweet point, then the
    # M=256 -> 512 drop at N=K=512 (1.97 -> 1.75 in the paper)
    t256 = evaluate(GEMM(256, 512, 512), D6_RF).tops_per_w
    t512 = evaluate(GEMM(512, 512, 512), D6_RF).tops_per_w
    t32 = evaluate(GEMM(32, 512, 512), D6_RF).tops_per_w
    assert t32 < t256
    assert t512 < t256


def test_headline_up_to_energy_gain():
    # abstract: up to 3.4x energy efficiency vs baseline — look for a shape
    # that achieves >= 3x among the calibration set
    best = 0.0
    for g in (BERT, GEMM(1024, 2048, 1024), GEMM(2048, 2048, 2048)):
        for cfg in (D6_RF, A6_RF, A8_RF):
            r = evaluate(g, cfg).tops_per_w / evaluate_baseline(g).tops_per_w
            best = max(best, r)
    assert best > 3.0
