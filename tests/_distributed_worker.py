"""Worker process for tests/test_distributed_sweep.py.

Each of the N cooperating OS processes runs this module: env-driven
`launch.distributed.initialize()` (REPRO_COORDINATOR / _NUM_PROCESSES /
_PROCESS_ID — the exact path a pod launcher uses), a `distributed_engine`
over the global row mesh with a chunk size forced small enough that the
golden grid streams through several tiles, then the full 1338-row
workload plan.  Every process writes its verdict rows + engine telemetry
to $WORKER_OUT.<process_index> so the driver can assert (a) bitwise
verdict equality with tests/golden/planner_verdicts.csv and (b) that all
hosts computed identical plans (SPMD: same grid, same reduction).

Standalone sanity run (single process, no coordinator → plain engine):

    PYTHONPATH=src:tests WORKER_OUT=/tmp/w python tests/_distributed_worker.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))


def main() -> None:
    from repro.launch import distributed as dist
    multi = dist.initialize()          # env-driven; no-op when unconfigured

    import jax
    assert multi == (jax.process_count() > 1), (multi, jax.process_count())

    chunk_rows = int(os.environ.get("WORKER_CHUNK_ROWS", "512"))
    engine = dist.distributed_engine(chunk_rows=chunk_rows)
    assert engine.n_shards == jax.device_count()

    from test_golden_verdicts import FIELDS, _verdict_rows
    from repro.core.sweep import plan_workload_batched

    # one definition of the golden row conventions (test_golden_verdicts)
    # with the decisions produced by THIS process's distributed engine
    rows = _verdict_rows(
        plan=lambda gemms: plan_workload_batched(gemms, engine=engine))
    assert all(set(r) == set(FIELDS) for r in rows)

    info = engine.cache_info()
    payload = {"process_index": jax.process_index(),
               "processes": jax.process_count(),
               "global_devices": jax.device_count(),
               "local_devices": jax.local_device_count(),
               "chunks": info["chunks"],
               "distributed": info["distributed"],
               "rows": rows}
    out = os.environ["WORKER_OUT"]
    with open(f"{out}.{jax.process_index()}", "w") as f:
        json.dump(payload, f)
    print("WORKER-OK", flush=True)


if __name__ == "__main__":
    main()
