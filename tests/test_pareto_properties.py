"""Property-based suite pinning the Pareto reduction's three layers to
each other (ISSUE 9 satellite): the scalar dominance reference, the
vectorized jit kernel, and the streaming accumulator.

Properties:
  * dominance is irreflexive and transitive, and exact ties dominate in
    neither direction;
  * the brute-force O(n^2) reference front matches the vectorized
    kernel bitwise on random (energy, latency, area) sets — ties,
    duplicates, and degenerate single-point grids included;
  * the front (as an index set) is invariant under row permutation and
    under arbitrary chunk-boundary placement through
    `ParetoAccumulator` — the identity the campaign's cross-chunk
    merging rests on.

Runs under real hypothesis when installed, else the deterministic
`_hypothesis_stub` registered by conftest.py.
"""
from __future__ import annotations

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_stub import given, settings, strategies as st

from repro.core.pareto import (ParetoAccumulator, dominates, pareto_mask,
                               pareto_mask_np, pareto_mask_ref)

# Integer-valued objectives drawn from a small range: collisions (exact
# ties, duplicate rows) are common, which is exactly the regime where a
# sloppy dominance predicate (<= instead of <) goes wrong.
coords = st.integers(min_value=0, max_value=6)
point3 = st.tuples(coords, coords, coords)
pointset = st.lists(point3, min_size=1, max_size=24)


def _arr(points) -> np.ndarray:
    return np.asarray(points, np.float32)


@given(point3)
@settings(max_examples=50)
def test_dominance_irreflexive(p):
    assert not dominates(p, p)


@given(point3, point3)
@settings(max_examples=100)
def test_dominance_antisymmetric(a, b):
    # a and b can never dominate each other simultaneously; exact ties
    # dominate in neither direction
    assert not (dominates(a, b) and dominates(b, a))
    if tuple(a) == tuple(b):
        assert not dominates(a, b) and not dominates(b, a)


@given(point3, point3, point3)
@settings(max_examples=150)
def test_dominance_transitive(a, b, c):
    if dominates(a, b) and dominates(b, c):
        assert dominates(a, c)


@given(pointset)
@settings(max_examples=80)
def test_vectorized_matches_bruteforce_bitwise(points):
    pts = _arr(points)
    ref = pareto_mask_ref(pts)
    vec = pareto_mask_np(pts)
    assert vec.dtype == np.bool_
    assert (ref == vec).all(), (pts, ref, vec)


def test_single_point_grid_is_its_own_front():
    assert pareto_mask_np(_arr([(3, 1, 4)])).tolist() == [True]
    assert pareto_mask_ref(_arr([(3, 1, 4)])).tolist() == [True]


def test_duplicate_rows_all_stay_on_front():
    pts = _arr([(1, 2, 3), (1, 2, 3), (9, 9, 9)])
    assert pareto_mask_np(pts).tolist() == [True, True, False]


def test_empty_set():
    assert pareto_mask_np(np.zeros((0, 3), np.float32)).shape == (0,)


def test_jit_kernel_accepts_traced_input():
    # pareto_mask itself is jit-compatible (the campaign promise);
    # compare an explicitly jitted call against the host path
    import jax
    pts = _arr([(1, 5, 2), (2, 2, 2), (3, 1, 9), (1, 5, 2)])
    jitted = np.asarray(jax.jit(pareto_mask)(pts))
    assert (jitted == pareto_mask_np(pts)).all()


@given(pointset, st.integers(min_value=0, max_value=2 ** 30))
@settings(max_examples=60)
def test_front_invariant_under_permutation(points, seed):
    pts = _arr(points)
    n = pts.shape[0]
    perm = np.random.RandomState(seed % (2 ** 31)).permutation(n)
    base = set(np.flatnonzero(pareto_mask_np(pts)).tolist())
    got_perm = pareto_mask_np(pts[perm])
    got = set(int(perm[i]) for i in np.flatnonzero(got_perm))
    assert got == base, (pts, perm)


@given(pointset, st.lists(st.integers(min_value=1, max_value=8),
                          min_size=1, max_size=6))
@settings(max_examples=60)
def test_front_invariant_under_chunk_placement(points, cuts):
    """Streaming the same rows through ParetoAccumulator under any
    chunk-boundary placement yields exactly the whole-batch front,
    points and indices both (bitwise: float32 in, float32 out)."""
    pts = _arr(points)
    n = pts.shape[0]
    whole = np.flatnonzero(pareto_mask_np(pts))

    acc = ParetoAccumulator(pts.shape[1])
    start = 0
    for c in cuts:
        stop = min(n, start + c)
        acc.update(pts[start:stop], np.arange(start, stop))
        start = stop
    acc.update(pts[start:], np.arange(start, n))   # remainder chunk

    front_pts, front_idx = acc.front()
    assert front_idx.tolist() == whole.tolist(), (pts, cuts)
    assert (front_pts == pts[whole]).all()
    assert acc.rows_seen == n
    assert len(acc) == len(whole)


def test_accumulator_rejects_nonfinite_and_bad_shapes():
    acc = ParetoAccumulator(3)
    with pytest.raises(ValueError, match="non-finite"):
        acc.update(_arr([(1, 2, np.inf)]), [0])
    with pytest.raises(ValueError, match=r"\(n, 3\)"):
        acc.update(np.zeros((2, 2), np.float32), [0, 1])
    with pytest.raises(ValueError, match="indices shape"):
        acc.update(np.zeros((2, 3), np.float32), [0])
    with pytest.raises(ValueError, match="n_objectives"):
        ParetoAccumulator(0)


def test_mask_np_rejects_non_matrix():
    with pytest.raises(ValueError, match=r"\(n, d\)"):
        pareto_mask_np(np.zeros(5, np.float32))
