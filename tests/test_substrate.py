"""Substrate tests: data pipeline, optimizers, quantization, checkpoints,
gradient compression, fault-tolerance runtime, sharding-rule legality."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, DataIterator, batch_at_step
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, linear_warmup_cosine)
from repro.optim.grad_compress import _quant, init_error_state
from repro.quant import (dequantize_weight, quantization_error,
                         quantize_tree, quantize_weight)
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import (FailureInjector, StragglerWatchdog,
                                         plan_elastic_mesh)

KEY = jax.random.PRNGKey(0)


# --- data pipeline ----------------------------------------------------------

def test_data_deterministic_and_skippable():
    dc = DataConfig(seed=7, vocab=101, seq_len=16, global_batch=4)
    b1 = batch_at_step(dc, 5)
    b2 = batch_at_step(dc, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    it = DataIterator(dc, start_step=5)
    b3 = next(it)
    np.testing.assert_array_equal(b1["tokens"], b3["tokens"])


def test_data_host_sharding_disjoint():
    dc0 = DataConfig(seed=1, vocab=50, seq_len=8, global_batch=8,
                     n_hosts=2, host_id=0)
    dc1 = DataConfig(seed=1, vocab=50, seq_len=8, global_batch=8,
                     n_hosts=2, host_id=1)
    b0 = batch_at_step(dc0, 3)
    b1 = batch_at_step(dc1, 3)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_data_targets_shifted():
    dc = DataConfig(seed=0, vocab=64, seq_len=8, global_batch=2)
    b = batch_at_step(dc, 0)
    assert b["tokens"].shape == b["targets"].shape == (2, 8)


# --- optimizers -------------------------------------------------------------

def _rosenbrockish(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum(params["b"] ** 2)


@pytest.mark.slow
@pytest.mark.parametrize("which", ["adamw", "adafactor"])
def test_optimizers_converge(which):
    params = {"w": jnp.zeros((4, 8)), "b": jnp.ones((8,))}
    init, update = ((adamw_init, adamw_update) if which == "adamw"
                    else (adafactor_init, adafactor_update))
    state = init(params)
    loss0 = float(_rosenbrockish(params))
    for _ in range(200):
        grads = jax.grad(_rosenbrockish)(params)
        if which == "adamw":
            params, state = adamw_update(params, grads, state, 0.05,
                                         weight_decay=0.0)
        else:
            params, state = adafactor_update(params, grads, state, 0.05)
    assert float(_rosenbrockish(params)) < 0.05 * loss0


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((128, 256))}
    st_a = adamw_init(params)
    st_f = adafactor_init(params)
    adam_bytes = sum(x.size for x in jax.tree.leaves(st_a))
    fact_bytes = sum(x.size for x in jax.tree.leaves(st_f))
    assert fact_bytes < adam_bytes / 50


def test_schedule_warmup_and_decay():
    lrs = [float(linear_warmup_cosine(s, 1e-3, 10, 100)) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] >= 1e-4 * 0.99


# --- quantization -------------------------------------------------------------

@given(st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_quantize_roundtrip_error_small(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 32))
    assert quantization_error(w) < 0.01


def test_quantize_tree_targets_matrices_only():
    tree = {"big": jnp.ones((512, 512)), "vec": jnp.ones((512,))}
    q = quantize_tree(tree, min_size=1024)
    assert isinstance(q["big"], dict) and q["big"]["q"].dtype == jnp.int8
    assert q["vec"].dtype == jnp.float32


# --- checkpointing --------------------------------------------------------------

def test_checkpoint_roundtrip_and_latest():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, tree, extra={"k": 1})
        ckpt.save(d, 7, tree)
        assert ckpt.latest_step(d) == 7
        got, extra = ckpt.restore(d, 3, tree)
        np.testing.assert_array_equal(np.asarray(got["a"]),
                                      np.asarray(tree["a"]))
        assert got["b"]["c"].dtype == jnp.bfloat16
        assert extra == {"k": 1}


def test_checkpoint_incomplete_ignored():
    tree = {"a": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 2, tree)
        # a crash between shard write and manifest: no manifest.json
        os.makedirs(os.path.join(d, "step_00000009"))
        assert ckpt.latest_step(d) == 2


def test_checkpoint_gc_keeps_recent():
    tree = {"a": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree)
        ckpt.gc_old(d, keep=2)
        assert ckpt.latest_step(d) == 5
        remaining = sorted(os.listdir(d))
        assert len([r for r in remaining if r.startswith("step_")]) == 2


# --- gradient compression ---------------------------------------------------------

def test_int8_quant_bounded_error():
    g = jax.random.normal(KEY, (256,)) * 0.01
    q, scale = _quant(g)
    back = q.astype(jnp.float32) * scale
    assert float(jnp.abs(back - g).max()) <= float(scale) * 0.51


def test_compressed_psum_error_feedback_converges():
    """Error feedback: the accumulated residual stays bounded and the
    mean of repeated compressed reductions tracks the true mean."""
    from repro.optim.grad_compress import compressed_psum

    def run(gs):
        errors = init_error_state({"g": gs[0]})
        outs = []
        for t in range(20):

            def body(g, e):
                r, ne = compressed_psum({"g": g}, {"g": e}, "i")
                return r["g"], ne["g"]
            red, err = jax.vmap(body, axis_name="i")(
                gs, jnp.broadcast_to(errors["g"], gs.shape))
            outs.append(red[0])
            errors = {"g": err[0]}
        return jnp.stack(outs)

    gs = jax.random.normal(KEY, (4, 64)) * 0.1
    red = run(gs)
    true = gs.mean(axis=0)
    err = jnp.abs(red.mean(axis=0) - true).max()
    assert float(err) < 0.02


# --- fault tolerance ---------------------------------------------------------------

def test_straggler_watchdog_flags_slow_step():
    w = StragglerWatchdog(threshold=2.0)
    import time
    for _ in range(10):
        w.step_start()
        time.sleep(0.002)
        assert not w.step_end()
    w.step_start()
    time.sleep(0.03)
    assert w.step_end()


def test_plan_elastic_mesh():
    assert plan_elastic_mesh(512, 16) == (32, 16)
    assert plan_elastic_mesh(504, 16) == (31, 16)   # lost one 8-chip host
    with pytest.raises(AssertionError):
        plan_elastic_mesh(8, 16)


def test_failure_injector():
    inj = FailureInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(RuntimeError):
        inj.check(3)


# --- sharding rules -----------------------------------------------------------------

def test_param_specs_and_legalize():
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCHS, RunConfig
    from repro.launch.mesh import abstract_mesh
    from repro.launch.specs import param_shapes
    from repro.sharding.rules import legalize, param_specs

    mesh = abstract_mesh((16, 16), ("data", "model"))
    cfg = ARCHS["qwen2-7b"]
    rc = RunConfig()
    shapes = param_shapes(cfg)
    specs = param_specs(shapes, cfg, rc)
    fixed = legalize(specs, shapes, mesh)

    flat_sh, _ = jax.tree_util.tree_flatten_with_path(shapes)
    flat_sp = jax.tree.leaves(fixed, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    for (path, leaf), spec in zip(flat_sh, flat_sp):
        for size, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            assert size % total == 0, (path, leaf.shape, spec)


def test_mamba_vocab_not_sharded_16way():
    from repro.configs import ARCHS, RunConfig
    from repro.launch.mesh import abstract_mesh
    from repro.launch.specs import param_shapes
    from repro.sharding.rules import legalize, param_specs
    mesh = abstract_mesh((16, 16), ("data", "model"))
    cfg = ARCHS["mamba2-780m"]           # vocab 50280 % 16 != 0
    shapes = param_shapes(cfg)
    specs = legalize(param_specs(shapes, cfg, RunConfig()), shapes, mesh)
    emb_spec = specs["embed"]
    assert emb_spec[0] is None           # dropped, not crashed
