"""Dry-run machinery test: one real cell lowered + compiled against the
production mesh in a subprocess (512 host-platform devices), plus unit
tests of the HLO collective parser and extrapolation math."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_collective_parser():
    from repro.launch.hlo_analysis import collective_stats
    hlo = """
  %ag = bf16[16,4096]{1,0} all-gather(%x), dimensions={0}
  %ar = f32[128]{0} all-reduce(%y), to_apply=%sum
  %cp = f32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %dot = f32[4,4]{1,0} dot(%a, %b)
"""
    st = collective_stats(hlo)
    assert st["by_type"]["all-gather"]["count"] == 1
    assert st["by_type"]["all-gather"]["bytes"] == 16 * 4096 * 2
    assert st["by_type"]["all-reduce"]["bytes"] == 128 * 4
    # all-reduce weighted 2x (ring traffic)
    want = 16 * 4096 * 2 + 2 * 128 * 4 + 16
    assert st["collective_bytes"] == want


def test_extrapolation_math():
    from repro.launch.dryrun import _extrapolate, _unroll_points
    # measured(k) = 100 + 7k  =>  true(L=28) = 100 + 196
    m = [(7, {"flops": 100 + 7 * 7}), (2, {"flops": 100 + 7 * 2})]
    out = _extrapolate(m, 28)
    assert out["flops"] == pytest.approx(100 + 7 * 28)
    assert _unroll_points(28) == [7, 2]
    assert _unroll_points(9) == [3, 1]
    assert _unroll_points(3) == [3]


def test_normalize_cost_analysis_dict_and_list():
    from repro.launch.dryrun import _normalize_cost_analysis
    # older jax: flat dict passes through
    d = {"flops": 8.0, "bytes accessed": 32.0}
    assert _normalize_cost_analysis(d) == d
    # newer jax: single-entry list is taken as-is
    assert _normalize_cost_analysis([d]) == d
    # multi-computation list: numeric values sum, others keep first
    merged = _normalize_cost_analysis(
        [{"flops": 8.0, "note": "a"}, {"flops": 4.0, "bytes accessed": 16.0}])
    assert merged["flops"] == 12.0
    assert merged["bytes accessed"] == 16.0
    assert merged["note"] == "a"
    # degenerate shapes
    assert _normalize_cost_analysis(None) == {}
    assert _normalize_cost_analysis([]) == {}
    assert _normalize_cost_analysis([None]) == {}


def test_unroll_points_divide():
    from repro.launch.dryrun import _unroll_points
    for L in (9, 20, 24, 28, 32, 40, 48, 64):
        pts = _unroll_points(L)
        assert all(L % k == 0 for k in pts), (L, pts)


@pytest.mark.slow
@pytest.mark.parametrize("cell", [("mamba2-780m", "decode_32k", "single")])
def test_dryrun_cell_compiles_on_production_mesh(cell, tmp_path):
    """Lower + compile one real (arch x shape) against the 16x16 mesh with
    512 placeholder devices — the deliverable-e mechanism, end to end."""
    arch, shape, mesh = cell
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--fast",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.load(open(tmp_path / f"{arch}.{shape}.{mesh}.json"))
    assert out["status"] == "ok", out
    assert out["chips"] == 256
    assert out["roofline"]["bottleneck"] in ("compute", "memory",
                                             "collective")
    # decode cells carry what/when/where verdicts + sweep-cache telemetry
    p = out["planner"]
    assert p["summary"]["n_gemms"] > 0
    assert p["plan_hits"] + p["plan_misses"] > 0
    assert p["cache"]["size"] > 0
    # per-backend keyspace breakdown + pallas fallback field ride along
    # in the embedded engine cache_info (report.py renders them)
    assert p["cache"]["backends"]["vectorized"]["misses"] > 0
    assert "pallas_fallback" in p["cache"]
