"""Unit + property tests for the core CiM library."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T, GEMM,
                        CiMSystemConfig, attention_gemms, conv2d_gemm,
                        evaluate, evaluate_baseline, evaluate_cim,
                        mac_energy_pj_from_tops_w, priority_map,
                        random_search, tech_scale_ratio)
from repro.core.loopnest import (coverage_factor, greedy_order,
                                 revisit_factor)
from repro.core.mapping import candidate_mappings

PRIMS = [ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T]

dims = st.integers(min_value=1, max_value=8192)
small_dims = st.sampled_from([1, 3, 16, 17, 64, 100, 256, 512, 1000, 4096])


# --- GEMM -----------------------------------------------------------------

def test_gemm_reuse_formula():
    g = GEMM(512, 1024, 1024)
    expect = 2 * 512 * 1024 * 1024 / (512 * 1024 + 1024 * 1024 + 512 * 1024)
    assert g.algorithmic_reuse == pytest.approx(expect)


def test_table_vi_reuse_values():
    # paper Table VI: BERT 512x1024x1024 -> reuse 512; GPT-J M=1 -> 1.999
    assert GEMM(512, 1024, 1024).algorithmic_reuse == pytest.approx(512.0)
    assert GEMM(1, 4096, 4096).algorithmic_reuse == pytest.approx(
        1.999, abs=1e-3)
    assert GEMM(12544, 64, 147).algorithmic_reuse == pytest.approx(
        88.86, rel=1e-3)


def test_conv_gemm_table1():
    g = conv2d_gemm(h_o=112, w_o=112, c_o=64, h_k=7, w_k=7, c_i=3)
    assert (g.M, g.N, g.K) == (12544, 64, 147)  # ResNet50 stem


def test_attention_gemms_table1():
    gs = attention_gemms(seq=512, d_model=1024, n_q_heads=16, n_kv_heads=16)
    by_label = {g.label.strip(): g for g in gs}
    assert by_label["QK^T"].M == 512 and by_label["QK^T"].N == 512
    assert by_label["Wq"].N == 1024 and by_label["Wq"].K == 1024


# --- technology scaling (eqs 2-5) -------------------------------------------

def test_tech_scale_identity_at_45nm_1v():
    assert tech_scale_ratio(1.0) == pytest.approx(1.0)


def test_mac_energy_from_tops_w():
    # 2/TOPS/W at 45nm/1V: an 89 TOPS/W macro -> ~22.5 fJ/MAC
    assert mac_energy_pj_from_tops_w(89.0) == pytest.approx(2 / 89)


# --- loop-nest reuse rule (Fig. 4) -------------------------------------------

def test_revisit_skips_leading_irrelevant():
    # K loop directly above a Z residency is skipped (psums accumulate)
    assert revisit_factor([("K", 4), ("M", 3)], "Z") == 3
    # ... but an outer irrelevant loop after a relevant one multiplies
    assert revisit_factor([("M", 3), ("K", 4)], "Z") == 12


def test_coverage_counts_relevant_only():
    assert coverage_factor([("M", 3), ("K", 4), ("N", 5)], "Z") == 15
    assert coverage_factor([("K", 4)], "Z") == 1


def test_greedy_order_smallest_outermost():
    order = greedy_order([("M", 3), ("K", 2), ("N", 8)])
    assert [f for _, f in order] == [8, 3, 2]  # innermost-first: descending


@given(fs=st.lists(st.integers(1, 64), min_size=3, max_size=3))
@settings(max_examples=25, deadline=None)
def test_revisit_at_least_coverage(fs):
    loops = list(zip("MKN", fs))
    for t in ("A", "W", "Z"):
        assert revisit_factor(loops, t) >= coverage_factor(loops, t)


# --- mapping validity (property) ---------------------------------------------

@given(m=small_dims, n=small_dims, k=small_dims,
       prim=st.sampled_from(PRIMS), level=st.sampled_from(["RF", "SMEM"]))
@settings(max_examples=60, deadline=None)
def test_priority_map_always_valid(m, n, k, prim, level):
    g = GEMM(m, n, k)
    cfg = CiMSystemConfig(prim=prim, cim_level=level)
    for mp in candidate_mappings(g, cfg):
        mp.validate()   # raises on violation
        assert 0 < mp.utilization <= 1.0


@given(m=small_dims, n=small_dims, k=small_dims,
       prim=st.sampled_from(PRIMS))
@settings(max_examples=40, deadline=None)
def test_metrics_sane(m, n, k, prim):
    g = GEMM(m, n, k)
    met = evaluate(g, CiMSystemConfig(prim=prim, cim_level="RF"))
    assert met.energy_pj > 0 and met.time_ns > 0
    assert met.gflops <= 1.05 * prim.peak_gops * 64  # generous physical cap
    # observed DRAM traffic can never be below the compulsory traffic
    assert met.dram_bytes >= g.input_elems + g.weight_elems \
        + g.output_elems - 1
    # energy is at least the pure MAC energy
    assert met.energy_pj >= g.macs * prim.mac_energy_pj


@given(m=small_dims, n=small_dims, k=small_dims)
@settings(max_examples=25, deadline=None)
def test_exact_order_never_worse_than_greedy(m, n, k):
    g = GEMM(m, n, k)
    cfg = CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF")
    exact = evaluate(g, cfg, order_mode="exact")
    greedy = evaluate(g, cfg, order_mode="greedy")
    assert exact.energy_pj <= greedy.energy_pj * 1.0001


@given(m=st.sampled_from([16, 64, 256, 1024]))
@settings(max_examples=10, deadline=None)
def test_energy_monotone_in_reuse(m):
    # for fixed weights, more M (more weight reuse) => never worse fJ/op
    cfg = CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF")
    e1 = evaluate(GEMM(m, 512, 512), cfg).fj_per_op
    e2 = evaluate(GEMM(4 * m, 512, 512), cfg).fj_per_op
    assert e2 <= e1 * 1.05


# --- heuristic search baseline ----------------------------------------------

def test_heuristic_never_beats_priority_much():
    # paper Fig. 7: the priority mapper wins on average; allow the random
    # search to tie but not to beat it by > 10 % on energy
    g = GEMM(512, 1024, 1024)
    cfg = CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF")
    ours = evaluate(g, cfg)
    found = random_search(g, cfg, seed=1, max_valid=300,
                          max_consecutive_invalid=20_000)
    assert found.best is not None
    assert found.best.energy_pj >= 0.9 * ours.energy_pj


def test_heuristic_terminates_and_reports():
    g = GEMM(16, 16, 16)
    cfg = CiMSystemConfig(prim=ANALOG_8T, cim_level="RF")
    res = random_search(g, cfg, seed=0, max_valid=50,
                        max_consecutive_invalid=200)
    assert res.valid > 0 and res.sampled >= res.valid
    assert res.best is not None


# --- baseline ----------------------------------------------------------------

def test_baseline_peak_bounded():
    m = evaluate_baseline(GEMM(4096, 4096, 4096))
    assert m.gflops <= 2048.0 + 1e-6


def test_baseline_handles_gemv():
    m = evaluate_baseline(GEMM(1, 1000, 2048))
    assert m.energy_pj > 0 and m.gflops > 0
