"""Decode hot-path optimizations: epilogue-fused INT8 dequant, KV-cache
buffer donation, the sync-free (pipelined) token loop, batched
first-logits fetch, and the Pallas block-size autotune table.

The contract under test: none of these optimizations may change the
math.  The fused dequant epilogue must match the canonical
`dequantize_weight` expression within float-reassociation tolerance on
every in-repo einsum spec (stacked experts included); the pipelined
engine must produce token streams EXACTLY equal to the synchronous
engine; donation must demonstrably update the cache pools in place; and
autotuned GEMM blocks must always be legal (divisible, VMEM-fitting).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, reduced
from repro.kernels.autotune import (INT8_GEMM_TABLE, SWEEP_ROW_LADDER,
                                    autotune_report, int8_gemm_blocks,
                                    int8_gemm_vmem_bytes, sweep_block_rows)
from repro.models import init, init_cache
from repro.quant.int8 import (dequant_contract, dequantize_weight,
                              quantize_weight)
from repro.serving import (ContinuousBatchingEngine, DecodeCore,
                           ServeSession, synthetic_requests)

RC = RunConfig(remat=False, attn_impl="naive")
MAX_LEN = 24
BLOCK = 4


def _core(arch: str):
    cfg = reduced(ARCHS[arch])
    params = init(jax.random.PRNGKey(0), cfg)
    return cfg, params, DecodeCore(cfg, RC, params, quantize=True,
                                   plan_batch=4, plan_max_len=MAX_LEN)


@pytest.fixture(scope="module")
def mamba():
    return _core("mamba2-780m")


@pytest.fixture(scope="module")
def attn():
    return _core("mistral-nemo-12b")


# --- epilogue-fused dequant --------------------------------------------------

def _quantized(key, k, n, stacked=()):
    w = jax.random.normal(key, (*stacked, k, n), jnp.float32)
    fn = quantize_weight
    for _ in stacked:
        fn = jax.vmap(fn)
    return fn(w)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dequant_contract_matches_reference(dtype):
    """Fused epilogue == canonical dequantize_weight contraction, and the
    output keeps the activation dtype (no silent f32 upcast)."""
    q, s = _quantized(jax.random.PRNGKey(0), 64, 48)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64), jnp.float32) \
        .astype(dtype)
    got = dequant_contract(x, q, s)
    ref = dequant_contract(x, q, s, materialize=True)
    assert got.dtype == dtype and ref.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)
    # and against the canonical expression itself
    ref2 = x @ dequantize_weight(q, s, dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref2, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("spec,x_shape,stacked", [
    # stacked MoE experts, both contraction directions (models/moe.py)
    ("ecd,edf->ecf", (3, 4, 16), (3,)),
    ("ecf,efd->ecd", (3, 4, 16), (3,)),
    # MoE decode fast path: all experts over the shared token batch
    ("td,edf->etf", (4, 16), (3,)),
    ("etf,efd->etd", (3, 4, 16), (3,)),
    # multi-head readout (models/layers.py audio head)
    ("bld,ndv->blnv", (2, 5, 16), (4,)),
])
def test_dequant_contract_stacked_specs(spec, x_shape, stacked):
    """Every in-repo einsum spec: the per-(expert, channel) scale applied
    as an output epilogue equals materializing each expert's weight."""
    q, s = _quantized(jax.random.PRNGKey(2), 16, 8, stacked)
    x = jax.random.normal(jax.random.PRNGKey(3), x_shape, jnp.float32)
    got = dequant_contract(x, q, s, spec)
    ref = dequant_contract(x, q, s, spec, materialize=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_dequant_contract_fallback_spec():
    """A spec whose scale axis is summed out of the output cannot take
    the epilogue path; dequant_contract must detect it (None from the
    reshape helper) and fall back to materializing — same answer."""
    from repro.quant.int8 import _epilogue_scale
    q, s = _quantized(jax.random.PRNGKey(4), 16, 8, (3,))
    assert _epilogue_scale("ab,cbd->ad", s) is None
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 16), jnp.float32)
    got = dequant_contract(x, q, s, "ab,cbd->ad")
    ref = dequant_contract(x, q, s, "ab,cbd->ad", materialize=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_moe_decode_fast_path_matches_buffered():
    """When every token fits expert capacity (T <= C — any decode
    micro-batch), dropping is impossible and the dense fast path must
    equal the scatter/gather dispatch exactly: same per-(expert, token)
    contractions, same k-ascending weighted sum."""
    from repro.models import moe
    cfg = reduced(ARCHS["qwen2-moe-a2.7b"])
    params = moe.moe_init(jax.random.PRNGKey(10), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(11), (8, 1, cfg.d_model),
                          jnp.float32)
    assert 8 <= moe.capacity(cfg, 8)
    y_fast, aux_f = moe.moe_apply(params, x, cfg)
    y_buf, aux_b = moe.moe_apply(params, x, cfg, force_buffered=True)
    np.testing.assert_array_equal(np.asarray(y_fast), np.asarray(y_buf))
    assert float(aux_f) == float(aux_b)


def test_epilogue_golden_logits_parity_mamba(mamba, monkeypatch):
    """Whole-model gate on the mixed-verdict mamba2 cell: decode logits
    with the fused epilogue vs a model traced with the canonical
    materializing dequant must agree within kernel-numerics tolerance
    and pick the same greedy tokens."""
    cfg, params, _ = mamba
    prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(7), (2, 5), 0, cfg.vocab))

    fused = ServeSession(cfg, RC, params, max_len=MAX_LEN, batch=2,
                         quantize=True)
    lf = np.asarray(fused.prefill(prompt), np.float32)

    import repro.models.layers as layers
    import repro.quant.int8 as int8mod
    ref_fn = lambda x, q, s, spec=None, **kw: dequant_contract(
        x, q, s, spec, materialize=True)
    monkeypatch.setattr(layers, "dequant_contract", ref_fn)
    monkeypatch.setattr(int8mod, "dequant_contract", ref_fn)
    ref = ServeSession(cfg, RC, params, max_len=MAX_LEN, batch=2,
                       quantize=True)
    lr = np.asarray(ref.prefill(prompt), np.float32)

    assert float(np.max(np.abs(lf - lr))) <= 0.05
    np.testing.assert_array_equal(lf[:, -1].argmax(-1),
                                  lr[:, -1].argmax(-1))


# --- buffer donation ---------------------------------------------------------

@pytest.fixture(scope="module")
def mamba_donating(mamba):
    """Same weights, donation forced on (the accelerator default; CPU
    defaults off because XLA:CPU's aliased program measured slower)."""
    cfg, params, _ = mamba
    return cfg, params, DecodeCore(cfg, RC, params, quantize=True,
                                   plan_batch=4, plan_max_len=MAX_LEN,
                                   donate=True)


def test_donation_defaults_per_platform(mamba):
    """donate=None resolves from the backend: off on CPU (where the
    aliased program is slower), on everywhere else."""
    _, _, core = mamba
    assert core.donate == (jax.default_backend() != "cpu")


def test_decode_core_step_donates_cache(mamba_donating):
    """With donation on, the jitted fixed-batch step consumes its cache
    argument: after one call the input pools are gone (aliased into the
    output), proving the multi-MB state updates in place instead of
    copying per token."""
    cfg, _, core = mamba_donating
    cache = jax.tree.map(jnp.asarray, init_cache(cfg, RC, 4, MAX_LEN))
    leaves = [l for l in jax.tree.leaves(cache) if hasattr(l, "is_deleted")]
    assert leaves, "cache has no donatable array leaves"
    tokens = jnp.zeros((4, 1), jnp.int32)
    _, cache2 = core.step(cache, tokens, jnp.int32(0))
    jax.block_until_ready(jax.tree.leaves(cache2)[0])
    assert all(l.is_deleted() for l in leaves)


def test_engine_donation_probe(mamba_donating, mamba):
    """The continuous engine's first-step probe reports donation took
    effect on a donating core; a non-donating core reports None (probe
    skipped), never a false failure."""
    for (cfg, _, core), want in ((mamba_donating, True),
                                 (mamba, None)):
        if core.donate:        # default CPU core: donation off -> None
            want = True
        eng = ContinuousBatchingEngine(core, n_slots=2, max_len=MAX_LEN,
                                       block_size=BLOCK)
        eng.run(synthetic_requests(cfg, 2, seed=0, prompt_len=(4, 6),
                                   new_tokens=(4, 6)), None)
        agg = eng.telemetry()["aggregate"]
        assert agg["kv_donation_ok"] is want


def test_donating_engine_tokens_match_default(mamba, mamba_donating):
    """Donation is an aliasing change only — token streams are exactly
    equal between a donating and a non-donating core."""
    cfg = mamba[0]
    streams = []
    for _, _, core in (mamba, mamba_donating):
        eng = ContinuousBatchingEngine(core, n_slots=3, max_len=MAX_LEN,
                                       block_size=BLOCK)
        reqs = synthetic_requests(cfg, 4, seed=3, prompt_len=(4, 7),
                                  new_tokens=(4, 7))
        eng.run(reqs, None)
        streams.append({r.rid: np.asarray(r.tokens).reshape(-1)
                        for r in eng.completed})
    assert streams[0].keys() == streams[1].keys()
    for rid in streams[0]:
        np.testing.assert_array_equal(streams[0][rid], streams[1][rid])


# --- sync-free (pipelined) token loop ----------------------------------------

def _stream(core, cfg, pipeline):
    eng = ContinuousBatchingEngine(core, n_slots=3, max_len=MAX_LEN,
                                   block_size=BLOCK, pipeline=pipeline,
                                   record_logits=True)
    reqs = synthetic_requests(cfg, 5, seed=1, prompt_len=(4, 8),
                              new_tokens=(4, 8))
    eng.run(reqs, None)
    assert len(eng.completed) == len(reqs)
    return eng, {r.rid: np.asarray(r.tokens).reshape(-1)
                 for r in eng.completed}


@pytest.mark.parametrize("arch_fixture", ["mamba", "attn"])
def test_pipelined_tokens_exactly_match_sync(arch_fixture, request):
    """The one-step-deep pipelined loop is a scheduling change only:
    token streams are EXACTLY the synchronous engine's, per request, on
    both the ssm and the paged-KV arch."""
    cfg, _, core = request.getfixturevalue(arch_fixture)
    eng_p, piped = _stream(core, cfg, pipeline=True)
    _, synced = _stream(core, cfg, pipeline=False)
    assert piped.keys() == synced.keys()
    for rid in piped:
        np.testing.assert_array_equal(piped[rid], synced[rid])
    # the pipelined run must actually have run pipelined (greedy traffic)
    bd = eng_p.telemetry()["aggregate"]["decode_step_breakdown"]
    assert bd["pipelined"] is True


def test_first_logits_batched_fetch_matches_legacy(mamba):
    """first_logits recorded through the batched one-transfer-per-step
    fetch equal the legacy session's prefill logits for each request."""
    cfg, params, core = mamba
    eng, _ = _stream(core, cfg, pipeline=True)
    legacy = ServeSession(cfg, RC, params, max_len=MAX_LEN, batch=1,
                          quantize=True)
    for r in eng.completed:
        assert r.first_logits is not None
        legacy.reset()
        ref = np.asarray(legacy.prefill(np.asarray(r.prompt)[None]),
                         np.float32)[0, -1]
        d = float(np.max(np.abs(np.asarray(r.first_logits,
                                           np.float32) - ref)))
        assert d <= 0.05


def test_step_breakdown_telemetry(mamba):
    """decode_step_breakdown accounts the host budget of every step."""
    cfg, _, core = mamba
    eng, _ = _stream(core, cfg, pipeline=True)
    bd = eng.telemetry()["aggregate"]["decode_step_breakdown"]
    assert bd["steps"] == eng.steps > 0
    for k in ("dispatch_s", "host_fetch_s", "telemetry_s",
              "dispatch_ms_per_step", "host_fetch_ms_per_step",
              "telemetry_ms_per_step"):
        assert bd[k] >= 0.0


def test_temperature_falls_back_to_sync(mamba):
    """Temperature sampling needs host logits every step: submitting one
    such request flips the engine out of pipelined mode (correctness
    over overlap) and everything still completes."""
    cfg, _, core = mamba
    eng = ContinuousBatchingEngine(core, n_slots=2, max_len=MAX_LEN,
                                   block_size=BLOCK, pipeline=True)
    reqs = synthetic_requests(cfg, 3, seed=2, prompt_len=(4, 6),
                              new_tokens=(4, 6))
    reqs[1].temperature = 0.8
    eng.run(reqs, None)
    assert len(eng.completed) == len(reqs)
    bd = eng.telemetry()["aggregate"]["decode_step_breakdown"]
    assert bd["pipelined"] is False


# --- block-size autotune table -----------------------------------------------

@pytest.mark.parametrize("M,N,K", [
    (1, 512, 256), (8, 512, 256), (8, 256, 2048), (64, 1024, 1024),
    (256, 128, 512), (1024, 1024, 1024), (4096, 96, 768), (7, 130, 96),
])
def test_int8_gemm_blocks_always_legal(M, N, K):
    """Whatever the table decides, the blocks satisfy the Pallas
    BlockSpec divisibility contract and fit the VMEM budget."""
    bm, bn, bk = int8_gemm_blocks(M, N, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    from repro.core.tpu_adapter import VMEM_BUDGET
    assert int8_gemm_vmem_bytes(bm, bn, bk) <= VMEM_BUDGET


def test_int8_gemm_table_shape_classes():
    """Decode GEMVs take the small-M entries (whole M resident, deep
    weight tile); prefill-scale GEMMs take the balanced entry."""
    bm, bn, bk = int8_gemm_blocks(8, 512, 1024)
    assert bm == 8 and bk > bn >= 256            # decode: K-deep tile
    bm2, _, _ = int8_gemm_blocks(4096, 4096, 4096)
    assert bm2 > 8                               # prefill: real M tiling
    report = autotune_report()
    assert {r["entry"] for r in report} <= \
        {name for name, _, _ in INT8_GEMM_TABLE} | {None}
    assert all(r["grid_steps"] >= 1 for r in report)


def test_int8_gemm_blocks_fallback_on_tiny_budget():
    """A budget the pinned entry cannot fit falls back to the analytic
    choose_blocks answer (never an illegal config)."""
    from repro.core.tpu_adapter import choose_blocks
    tiny = 64 * 1024
    assert int8_gemm_blocks(256, 512, 512, vmem=tiny) == \
        choose_blocks(256, 512, 512, vmem=tiny)


def test_int8_matmul_autotuned_matches_reference():
    """ops.int8_matmul with table-chosen blocks == the canonical
    dequantized matmul (same gate the fixed-256 config passed)."""
    from repro.kernels import ops
    q, s = _quantized(jax.random.PRNGKey(8), 256, 128)
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 256), jnp.float32)
    got = np.asarray(ops.int8_matmul(x, q, s))
    ref = np.asarray(x @ dequantize_weight(q, s))
    np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)


def test_sweep_block_rows_ladder():
    """Planner-sized batches take one grid step; the choice always comes
    from the ladder; a starved budget degrades to the smallest entry."""
    n_fields, n_out = 40, 11
    for n_rows in (100, 1024, 5000, 8192):
        blk = sweep_block_rows(n_rows, n_fields, n_out)
        assert blk in SWEEP_ROW_LADDER
        if blk < max(SWEEP_ROW_LADDER):
            assert blk >= min(n_rows, blk)       # ladder-legal cap
    assert sweep_block_rows(5000, n_fields, n_out) >= 5000  # single step
    assert sweep_block_rows(10 ** 6, n_fields, n_out,
                            vmem=1) == SWEEP_ROW_LADDER[0]
