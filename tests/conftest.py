import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def engine():
    """Shared unsharded SweepEngine: session-scoped so the jitted sweep
    kernels compile once and parity tests reuse one warm LRU instead of
    re-evaluating identical (GEMM, config) pairs per test."""
    from repro.core.sweep import SweepEngine
    return SweepEngine(mesh=None)

# Property tests use `hypothesis`; offline environments (no wheel baked into
# the image) fall back to the deterministic stub in _hypothesis_stub.py.
# CI installs the real package via the `test` extra and skips this branch.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
