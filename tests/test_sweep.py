"""Batched sweep engine vs the scalar planner: verdict parity (exact AND
greedy order modes, both fully in-kernel), metric parity (including the
CiM@SMEM and baseline scoring the vectorized model gained), sharded-vs-
unsharded bitwise parity (forced 1-device row mesh), LRU-cache behavior +
thread safety, the one-registry jit cache clear, and the summarize()
eligibility fix."""
import threading

import jax
import numpy as np
import pytest

from repro.core import (DIGITAL_6T, GEMM, CiMSystemConfig, Decision,
                        decide, evaluate, evaluate_baseline, make_decision,
                        plan_workload, standard_configs, summarize)
from repro.core.cost_model import Metrics, metrics_from_row
from repro.core.sweep import SweepEngine

# paper-flavored shape grid: BERT layer, GPT-J decode GEMV, ResNet stem,
# batched decode FFN, squares, and awkward non-pow2 dims
PAPER_GEMMS = [
    GEMM(512, 1024, 1024),      # BERT-Large projection
    GEMM(1, 4096, 4096),        # GPT-J M=1 decode (the "when NOT to CiM")
    GEMM(12544, 64, 147),       # ResNet50 stem conv-as-GEMM
    GEMM(128, 5632, 2048),      # batched decode FFN
    GEMM(4096, 1408, 2048),     # train-shape expert GEMM
    GEMM(256, 256, 256),
    GEMM(17, 100, 300),         # non-pow2 everything
    GEMM(1, 32, 64),            # tiny GEMV
]

CONFIGS = standard_configs()


def _llm_gemms():
    """One assigned arch's full llm_workloads GEMM set (train + decode) —
    the greedy parity suite sweeps these on top of PAPER_GEMMS."""
    from repro.configs import ARCHS, SHAPES
    from repro.core.llm_workloads import gemms_of_model
    out = []
    for sname in ("train_4k", "decode_32k"):
        out += gemms_of_model(ARCHS["qwen2-7b"], SHAPES[sname])
    return out


@pytest.fixture(scope="session")
def plans_exact():
    """Both backends over PAPER_GEMMS, order_mode="exact" — computed once
    per session (the scalar path is the expensive reference)."""
    dv = plan_workload(PAPER_GEMMS, CONFIGS, backend="vectorized")
    ds = plan_workload(PAPER_GEMMS, CONFIGS, backend="scalar")
    return dv, ds


@pytest.fixture(scope="session")
def plans_greedy():
    """Both backends under order_mode="greedy" over llm_workloads GEMMs +
    the paper grid — the path that used to silently fall back to scalar."""
    gemms = _llm_gemms() + PAPER_GEMMS
    dv = plan_workload(gemms, CONFIGS, order_mode="greedy",
                       backend="vectorized")
    ds = plan_workload(gemms, CONFIGS, order_mode="greedy",
                       backend="scalar")
    return gemms, dv, ds


def _tie_ok(name_a, name_b, opts_a, base_a, tol=0.02):
    """Verdicts may differ only on float32 near-ties: the two chosen
    options' efficiencies must then be within `tol`."""
    def topsw(name):
        return (base_a.tops_per_w if name == "baseline"
                else opts_a[name].tops_per_w)
    ta, tb = topsw(name_a), topsw(name_b)
    return abs(ta - tb) <= tol * max(ta, tb)


@pytest.mark.parametrize("i", range(len(PAPER_GEMMS)),
                         ids=[f"{g.M}x{g.N}x{g.K}" for g in PAPER_GEMMS])
def test_verdict_parity_all_standard_configs(i, plans_exact):
    dv, ds = (p[i] for p in plans_exact)
    gemm = PAPER_GEMMS[i]
    assert dv.use_cim == ds.use_cim, (gemm, dv.best_energy, ds.best_energy)
    assert (dv.best_energy == ds.best_energy
            or _tie_ok(dv.best_energy, ds.best_energy, ds.options,
                       ds.baseline)), (gemm, dv.best_energy, ds.best_energy)


def test_option_metric_parity_all_standard_configs(plans_exact):
    dvs, dss = plans_exact
    for gemm, dv, ds in list(zip(PAPER_GEMMS, dvs, dss))[:4]:
        assert dv.baseline.energy_pj == pytest.approx(
            ds.baseline.energy_pj, rel=0.02)
        assert dv.baseline.time_ns == pytest.approx(
            ds.baseline.time_ns, rel=0.02)
        for name in CONFIGS:
            assert dv.options[name].energy_pj == pytest.approx(
                ds.options[name].energy_pj, rel=0.02), (gemm, name)
            assert dv.options[name].time_ns == pytest.approx(
                ds.options[name].time_ns, rel=0.02), (gemm, name)


def test_plan_workload_backends_agree(plans_exact):
    for a, b in zip(*plans_exact):
        assert a.use_cim == b.use_cim
        assert (a.best_energy == b.best_energy
                or _tie_ok(a.best_energy, b.best_energy, b.options,
                           b.baseline))


# --- greedy order mode: in-kernel per-row order selection ------------------


def test_greedy_verdict_parity_llm_workloads(plans_greedy):
    """vectorized greedy verdicts == scalar greedy verdicts across
    llm_workloads x standard_configs (PR-2 tentpole: no scalar
    fallback)."""
    gemms, dvs, dss = plans_greedy
    for g, a, b in zip(gemms, dvs, dss):
        assert a.use_cim == b.use_cim, (g, a.best_energy, b.best_energy)
        assert (a.best_energy == b.best_energy
                or _tie_ok(a.best_energy, b.best_energy, b.options,
                           b.baseline)), (g, a.best_energy, b.best_energy)


def test_greedy_option_metric_parity(plans_greedy):
    gemms, dvs, dss = plans_greedy
    for g, dv, ds in list(zip(gemms, dvs, dss))[:6]:
        for name in CONFIGS:
            assert dv.options[name].energy_pj == pytest.approx(
                ds.options[name].energy_pj, rel=0.02), (g, name)
            assert dv.options[name].time_ns == pytest.approx(
                ds.options[name].time_ns, rel=0.02), (g, name)


def test_greedy_mask_matches_loopnest_reference():
    """The in-kernel one-hot order selection == loopnest.greedy_order for
    every trip-count pattern, ties included."""
    import itertools
    import jax.numpy as jnp
    from repro.core.loopnest import greedy_perm
    from repro.core.vectorized import _ORDERS, _greedy_mask
    patterns = list(itertools.product([1, 2, 3, 7], repeat=3))
    trips = {d: jnp.asarray([float(p[i]) for p in patterns])
             for i, d in enumerate(("M", "K", "N"))}
    masks = np.stack([np.asarray(_greedy_mask(trips, o)) for o in _ORDERS])
    assert (masks.sum(axis=0) == 1).all()      # exactly one order per row
    for r, p in enumerate(patterns):
        picked = _ORDERS[int(np.argmax(masks[:, r]))]
        want = greedy_perm({"M": p[0], "K": p[1], "N": p[2]})
        assert tuple(picked) == want, (p, picked, want)


def test_greedy_runs_with_zero_scalar_fallback(monkeypatch):
    """The batched greedy path must never touch the scalar cost model —
    poison it and score a full config sweep through a fresh engine (fresh
    LRU, so every pair really hits the device kernel)."""
    import repro.core.sweep as sweep_mod

    def boom(*a, **k):
        raise AssertionError("scalar fallback invoked on the batched path")
    monkeypatch.setattr(sweep_mod, "evaluate", boom)
    eng = SweepEngine(mesh=None)
    pairs = [(PAPER_GEMMS[0], cfg) for cfg in CONFIGS.values()]
    mets = eng.cim_metrics(pairs, order_mode="greedy")
    assert len(mets) == len(pairs)
    assert all(np.isfinite(m.energy_pj) for m in mets)


# --- sharded evaluation ----------------------------------------------------


def test_sharded_engine_bitwise_parity_1device_mesh():
    """An explicit 1-device row mesh exercises the shard_map path on a
    single host device; sharding is a pure data split, so metrics must be
    bitwise identical to the unsharded engine.  (The multi-device split
    is covered by the @slow subprocess test and the benchmark gate.)"""
    from repro.launch.mesh import row_mesh
    mesh = row_mesh(jax.devices()[:1])
    es = SweepEngine(mesh=mesh)
    eu = SweepEngine(mesh=None)
    assert es.n_shards == 1
    gemms = [PAPER_GEMMS[0], PAPER_GEMMS[1]]
    pairs = [(g, CONFIGS[n]) for g in gemms
             for n in ("Digital-6T@RF", "Digital-6T@SMEM-B",
                       "Analog-8T@SMEM-A")]
    for om in ("exact", "greedy"):
        for a, b in zip(es.cim_metrics(pairs, om),
                        eu.cim_metrics(pairs, om)):
            assert a.energy_pj == b.energy_pj     # bitwise, not approx
            assert a.time_ns == b.time_ns
            assert a.dram_bytes == b.dram_bytes
    # (sharded baseline parity: @slow subprocess test + the benchmark's
    # sharded plan_workload gate — its 36-order kernel compile is too
    # heavy for the fast tier)


@pytest.mark.slow
def test_sharded_engine_parity_multidevice_subprocess():
    """Real row-axis split: 4 forced host devices in a subprocess, bitwise
    parity of the sharded vs unsharded engine over the paper grid."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    code = """
import jax
assert len(jax.devices()) == 4
from repro.core import GEMM
from repro.core.planner import standard_configs
from repro.core.sweep import SweepEngine
from repro.launch.mesh import row_mesh
CONFIGS = standard_configs()
es = SweepEngine(mesh=row_mesh())
eu = SweepEngine(mesh=None)
assert es.n_shards == 4
gemms = [GEMM(512,1024,1024), GEMM(1,4096,4096), GEMM(17,100,300),
         GEMM(4096,1408,2048)]
pairs = [(g, c) for g in gemms for c in CONFIGS.values()]
for om in ("exact", "greedy"):
    for a, b in zip(es.cim_metrics(pairs, om), eu.cim_metrics(pairs, om)):
        assert a.energy_pj == b.energy_pj and a.time_ns == b.time_ns
for a, b in zip(es.baseline_metrics(gemms), eu.baseline_metrics(gemms)):
    assert a.energy_pj == b.energy_pj and a.time_ns == b.time_ns
print("OK")
"""
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=repo,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


# --- other vectorized-model parity -----------------------------------------


def test_smem_config_batch_matches_scalar(engine):
    """The vectorized model's CiM@SMEM scoring (configA/B) matches
    cost_model.evaluate."""
    for g in (GEMM(512, 1024, 1024), GEMM(1, 4096, 4096),
              GEMM(128, 5632, 2048)):
        for name in ("Digital-6T@SMEM-A", "Digital-6T@SMEM-B",
                     "Analog-8T@SMEM-B"):
            cfg = CONFIGS[name]
            m_s = evaluate(g, cfg)
            m_v = engine.cim_metrics([(g, cfg)])[0]
            assert m_v.energy_pj == pytest.approx(m_s.energy_pj, rel=0.02)
            assert m_v.time_ns == pytest.approx(m_s.time_ns, rel=0.02)


def test_baseline_batch_matches_scalar(engine):
    """The vectorized model's tensor-core baseline scoring matches
    baseline.evaluate_baseline."""
    for g in PAPER_GEMMS:
        m_s = evaluate_baseline(g)
        m_v = engine.baseline_metrics([g])[0]
        assert m_v.energy_pj == pytest.approx(m_s.energy_pj, rel=0.02), g
        assert m_v.time_ns == pytest.approx(m_s.time_ns, rel=0.02), g


# --- cache behavior --------------------------------------------------------


def test_sweep_cache_hits_and_identity():
    eng = SweepEngine(mesh=None)
    g = GEMM(256, 512, 512)
    cfg = CONFIGS["Digital-6T@RF"]
    m1 = eng.cim_metrics([(g, cfg)])[0]
    assert eng.cache_info()["misses"] == 1
    m2 = eng.cim_metrics([(g, cfg)])[0]
    assert m2 is m1                       # cached object, no re-evaluation
    assert eng.cache_info()["hits"] == 1
    # greedy results cache under a distinct key
    mg = eng.cim_metrics([(g, cfg)], order_mode="greedy")[0]
    assert mg is not m1
    assert eng.cim_metrics([(g, cfg)], order_mode="greedy")[0] is mg
    # label/count do not affect metrics: same cache entry
    m3 = eng.cim_metrics([(g.scaled(label="x", count=7), cfg)])[0]
    assert m3 is m1
    # eviction respects the LRU bound
    small = SweepEngine(cache_size=2, mesh=None)
    for m in (16, 32, 64, 128):
        small.baseline_metrics([GEMM(m, 64, 64)])
    assert small.cache_info()["size"] == 2


def test_engine_cache_thread_safety():
    """Concurrent kernel_plan-style queries against ONE shared engine:
    the locked LRU must neither corrupt (OrderedDict invariants) nor lose
    hit/miss counts, even with eviction churn (tiny cache_size)."""
    eng = SweepEngine(cache_size=16, mesh=None)
    gemms = [GEMM(16 * (1 + i % 8), 32 * (1 + i % 3), 64 + 32 * (i % 4))
             for i in range(24)]
    cfgs = [CONFIGS[n] for n in ("Digital-6T@RF", "Analog-6T@RF",
                                 "Digital-6T@SMEM-B")]
    # prewarm the jitted kernels so threads only race the cache, not the
    # first-compile path
    eng.cim_metrics([(gemms[0], cfgs[0])])
    n_threads, n_iter = 8, 40
    errors: list = []
    local_counts: list = []

    def work(t):
        try:
            for i in range(n_iter):
                g = gemms[(t * 7 + i) % len(gemms)]
                c = cfgs[(t + i) % len(cfgs)]
                m = eng.cim_metrics([(g, c)])[0]
                assert np.isfinite(m.energy_pj)
            local_counts.append(eng.thread_cache_counts())
        except Exception as e:            # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors, errors
    info = eng.cache_info()
    assert info["size"] <= 16
    # every locked _get incremented exactly one counter
    assert info["hits"] + info["misses"] == 1 + n_threads * n_iter
    # per-thread attribution (measured_cache_delta's basis): each thread
    # saw exactly its own n_iter lookups, and the locals sum to the
    # global counters (main thread did the 1 prewarm lookup)
    assert all(h + m == n_iter for h, m in local_counts)
    assert sum(h + m for h, m in local_counts) == n_threads * n_iter


# --- pallas backend --------------------------------------------------------


def test_pallas_backend_parity_and_cache_keyspace():
    """backend="pallas" runs the fused kernel into its OWN result-cache
    keyspace (a shared keyspace would let parity tests pass on LRU hits
    instead of kernel output), and cache_info() breaks hits/misses down
    per backend."""
    eng = SweepEngine(mesh=None)
    g = GEMM(512, 1024, 1024)
    cfg = CONFIGS["Digital-6T@RF"]
    mv = eng.cim_metrics([(g, cfg)], backend="vectorized")[0]
    mp = eng.cim_metrics([(g, cfg)], backend="pallas")[0]
    assert mp is not mv                    # distinct keyspaces, both cold
    assert mp.energy_pj == pytest.approx(mv.energy_pj, rel=1e-5)
    assert mp.time_ns == pytest.approx(mv.time_ns, rel=1e-5)
    assert eng.cim_metrics([(g, cfg)], backend="pallas")[0] is mp
    info = eng.cache_info()
    assert info["backends"]["vectorized"] == {"hits": 0, "misses": 1}
    assert info["backends"]["pallas"] == {"hits": 1, "misses": 1}
    assert info["pallas_fallback"] is None
    # scalar-reference agreement (the property suite covers this wide;
    # here it pins the engine-level path end to end)
    ms = evaluate(g, cfg)
    assert mp.energy_pj == pytest.approx(ms.energy_pj, rel=0.02)


def test_pallas_fallback_records_reason(monkeypatch):
    """On a platform whose Pallas lowering is unavailable, a pallas
    request transparently reuses the XLA kernel + vectorized keyspace and
    cache_info()/telemetry say so."""
    import repro.kernels.sweep_eval as se
    monkeypatch.setattr(se, "_STATUS",
                        {"mode": "unavailable",
                         "reason": "gpu: NotImplementedError: no lowering"})
    eng = SweepEngine(mesh=None)
    g = GEMM(256, 512, 512)
    cfg = CONFIGS["Analog-8T@SMEM-A"]
    mp = eng.cim_metrics([(g, cfg)], backend="pallas")[0]
    info = eng.cache_info()
    assert info["pallas_fallback"] == ("gpu: NotImplementedError: "
                                       "no lowering")
    assert "pallas" not in info["backends"]          # keyspace unused
    assert info["backends"]["vectorized"]["misses"] == 1
    # the fallback result IS the vectorized entry (no double evaluation)
    assert eng.cim_metrics([(g, cfg)], backend="vectorized")[0] is mp
    # fallback reason survives cache_clear (platform fact, not cache state)
    eng.cache_clear()
    assert eng.cache_info()["pallas_fallback"] is not None


def test_measured_cache_delta_carries_backend_breakdown():
    """Serving/dryrun telemetry consumers read measured_cache_delta's
    engine block — the per-backend breakdown and fallback field must be
    in it (launch.serve prints it; dryrun decode cells embed it)."""
    from repro.core.sweep import measured_cache_delta, sweep_evaluate
    g = GEMM(96, 160, 224)
    _, tel = measured_cache_delta(
        lambda: sweep_evaluate(g, CONFIGS["Digital-8T@RF"]))
    assert tel["plan_hits"] + tel["plan_misses"] >= 1
    eng = tel["engine"]
    assert "backends" in eng and "pallas_fallback" in eng
    assert eng["backends"]["vectorized"]["misses"] >= 1


def test_report_renders_backend_breakdown():
    """launch.report's planner-cache table shows the per-backend counts
    and flags a recorded pallas fallback; cells predating the fields
    still render."""
    from repro.launch.report import planner_cache_table
    base = {"status": "ok", "arch": "a", "shape": "s", "mesh": "single"}
    planner = {"summary": {"cim_fraction": 0.5, "energy_gain_x": 2.0},
               "plan_hits": 3, "plan_misses": 4,
               "cim_routed_fraction": 0.25,
               "cache": {"hits": 7, "misses": 9, "size": 16,
                         "backends": {"vectorized": {"hits": 5,
                                                     "misses": 6},
                                      "pallas": {"hits": 2, "misses": 3}},
                         "pallas_fallback": "gpu: no lowering"}}
    table = planner_cache_table([{**base, "planner": planner}])
    assert "vectorized:5h/6m" in table
    assert "pallas:2h/3m" in table
    assert "pallas→xla" in table
    legacy = {**planner, "cache": {"hits": 1, "misses": 2, "size": 3}}
    assert "size=3" in planner_cache_table([{**base, "planner": legacy}])


# --- argument validation ---------------------------------------------------


def test_unknown_backend_rejected():
    g = GEMM(64, 64, 64)
    with pytest.raises(ValueError, match="unknown planner backend"):
        decide(g, backend="vectorised")
    with pytest.raises(ValueError, match="unknown planner backend"):
        plan_workload([g], backend="batched")
    with pytest.raises(ValueError, match="unknown planner backend"):
        plan_workload([g], backend="palas")
    with pytest.raises(ValueError, match="unknown sweep backend"):
        SweepEngine(mesh=None).cim_metrics(
            [(g, CONFIGS["Digital-6T@RF"])], backend="xla")


def test_unknown_order_mode_rejected_by_both_backends():
    """Satellite fix: no silent reroute, no asymmetric errors — both
    backends accept exactly {exact, greedy} and reject the rest."""
    g = GEMM(64, 64, 64)
    for backend in ("vectorized", "scalar"):
        with pytest.raises(ValueError, match="unknown order_mode"):
            decide(g, order_mode="greddy", backend=backend)
        with pytest.raises(ValueError, match="unknown order_mode"):
            plan_workload([g], order_mode="fastest", backend=backend)
    with pytest.raises(ValueError, match="unknown order_mode"):
        SweepEngine(mesh=None).cim_metrics(
            [(g, CONFIGS["Digital-6T@RF"])], order_mode="greddy")


def test_order_mode_greedy_stays_batched():
    """decide(order_mode="greedy", backend="vectorized") now scores
    in-kernel (and agrees with scalar) instead of silently falling back."""
    g = GEMM(256, 512, 512)
    d = decide(g, CONFIGS, order_mode="greedy", backend="vectorized")
    ds = decide(g, CONFIGS, order_mode="greedy", backend="scalar")
    assert d.best_energy == ds.best_energy
    # and the engine accepts greedy directly (no ValueError)
    m = SweepEngine(mesh=None).cim_metrics(
        [(g, CONFIGS["Digital-6T@RF"])], order_mode="greedy")[0]
    assert isinstance(m, Metrics)


# --- decision layer --------------------------------------------------------


def _fake_metrics(energy, time):
    return metrics_from_row(1000.0, {"energy_pj": energy, "time_ns": time})


def test_summarize_uses_eligible_winner():
    """energy_gain_x must come from the option decide() deploys, not from
    an unconstrained min-energy config the throughput floor rules out."""
    g = GEMM(64, 64, 64)
    base = _fake_metrics(energy=100.0, time=10.0)          # 100 gflops eq.
    options = {
        # eligible winner: keeps throughput, halves energy
        "good": _fake_metrics(energy=50.0, time=12.0),
        # ineligible tempter: 10x energy win but 100x throughput collapse
        "slow": _fake_metrics(energy=10.0, time=1000.0),
    }
    d = make_decision(g, base, options, throughput_floor=0.5)
    assert d.best_energy == "good"
    s = summarize([d])
    assert s["energy_gain_x"] == pytest.approx(100.0 / 50.0)


def test_make_decision_shared_by_both_backends():
    g = GEMM(512, 1024, 1024)
    ds = decide(g, CONFIGS, backend="scalar")
    rebuilt = make_decision(g, ds.baseline, ds.options)
    assert rebuilt.best_energy == ds.best_energy
    assert rebuilt.use_cim == ds.use_cim


# NOTE: defined last on purpose — it drops every compiled sweep kernel,
# so any test running after it would pay a recompile.
def test_jit_cache_clear_covers_every_kernel():
    # benchmarks drop the compiled kernels to take an honest cold-jit
    # sample; ALL registered entry points (exact, greedy, sharded) must
    # go cold, and recompiling must reproduce identical metrics
    from repro.core.sweep import jit_cache_clear, jit_kernel_count
    from repro.launch.mesh import row_mesh
    eng = SweepEngine(mesh=None)
    sharded = SweepEngine(mesh=row_mesh(jax.devices()[:1]))
    g = GEMM(64, 128, 128)
    cfg = CONFIGS["Digital-6T@RF"]
    before = eng.cim_metrics([(g, cfg)])[0]
    eng.cim_metrics([(g, cfg)], order_mode="greedy")
    eng.cim_metrics([(g, cfg)], backend="pallas")
    sharded.cim_metrics([(g, cfg)])
    assert jit_kernel_count() > 0
    jit_cache_clear()
    assert jit_kernel_count() == 0        # no stale executable survives
    eng.cache_clear()
    after = eng.cim_metrics([(g, cfg)])[0]
    assert after.energy_pj == before.energy_pj
    assert after.time_ns == before.time_ns


@pytest.mark.slow
def test_serving_kernel_plan_gates_decode_gemvs():
    """ServeSession consults the batched planner: per-token decode GEMMs
    of a tiny model are "don't CiM" (the paper's M=1 pathology), and the
    build records sweep-cache telemetry for LRU sizing."""
    from repro.configs import ARCHS, RunConfig, reduced
    from repro.models import init
    from repro.serving import ServeSession
    import jax

    cfg = reduced(ARCHS["qwen2-7b"])
    rc = RunConfig(remat=False, attn_impl="naive")
    params = init(jax.random.PRNGKey(0), cfg)
    s = ServeSession(cfg, rc, params, max_len=32, batch=2)
    plan = s.kernel_plan
    assert plan and all(isinstance(d, Decision) for d in plan.values())
    assert s.kernel_plan is plan          # lazily computed once
    # batch-2 decode: every GEMM is tiny/low-reuse -> nothing offloads
    gemvs = [lab for lab in plan if "decode" in lab or "Wq" in lab]
    assert gemvs
    for lab in gemvs:
        assert s.use_cim_for(lab) == plan[lab].use_cim
    # unknown labels raise (label drift must not silently disable gating)
    with pytest.raises(KeyError):
        s.use_cim_for("no-such-gemm")
    # cache telemetry: one plan build = one hit-or-miss per (gemm, config)
    # option plus one per baseline, recorded for traffic-driven sizing
    tel = s.plan_cache_telemetry
    assert tel["plan_hits"] + tel["plan_misses"] >= len(plan)
    assert tel["engine"]["hits"] >= tel["plan_hits"]
