"""Batched sweep engine vs the scalar planner: verdict parity, metric
parity (including the CiM@SMEM and baseline scoring the vectorized model
gained), LRU-cache behavior, and the summarize() eligibility fix."""
import numpy as np
import pytest

from repro.core import (DIGITAL_6T, GEMM, CiMSystemConfig, Decision,
                        decide, evaluate, evaluate_baseline, make_decision,
                        plan_workload, standard_configs, summarize)
from repro.core.cost_model import Metrics, metrics_from_row
from repro.core.sweep import SweepEngine, decide_batched

# paper-flavored shape grid: BERT layer, GPT-J decode GEMV, ResNet stem,
# batched decode FFN, squares, and awkward non-pow2 dims
PAPER_GEMMS = [
    GEMM(512, 1024, 1024),      # BERT-Large projection
    GEMM(1, 4096, 4096),        # GPT-J M=1 decode (the "when NOT to CiM")
    GEMM(12544, 64, 147),       # ResNet50 stem conv-as-GEMM
    GEMM(128, 5632, 2048),      # batched decode FFN
    GEMM(4096, 1408, 2048),     # train-shape expert GEMM
    GEMM(256, 256, 256),
    GEMM(17, 100, 300),         # non-pow2 everything
    GEMM(1, 32, 64),            # tiny GEMV
]

CONFIGS = standard_configs()


def _tie_ok(name_a, name_b, opts_a, base_a, tol=0.02):
    """Verdicts may differ only on float32 near-ties: the two chosen
    options' efficiencies must then be within `tol`."""
    def topsw(name):
        return (base_a.tops_per_w if name == "baseline"
                else opts_a[name].tops_per_w)
    ta, tb = topsw(name_a), topsw(name_b)
    return abs(ta - tb) <= tol * max(ta, tb)


@pytest.mark.parametrize("gemm", PAPER_GEMMS,
                         ids=[f"{g.M}x{g.N}x{g.K}" for g in PAPER_GEMMS])
def test_verdict_parity_all_standard_configs(gemm):
    dv = decide(gemm, CONFIGS, backend="vectorized")
    ds = decide(gemm, CONFIGS, backend="scalar")
    assert dv.use_cim == ds.use_cim, (gemm, dv.best_energy, ds.best_energy)
    assert (dv.best_energy == ds.best_energy
            or _tie_ok(dv.best_energy, ds.best_energy, ds.options,
                       ds.baseline)), (gemm, dv.best_energy, ds.best_energy)


def test_option_metric_parity_all_standard_configs():
    for gemm in PAPER_GEMMS[:4]:
        ds = decide(gemm, CONFIGS, backend="scalar")
        dv = decide(gemm, CONFIGS, backend="vectorized")
        assert dv.baseline.energy_pj == pytest.approx(
            ds.baseline.energy_pj, rel=0.02)
        assert dv.baseline.time_ns == pytest.approx(
            ds.baseline.time_ns, rel=0.02)
        for name in CONFIGS:
            assert dv.options[name].energy_pj == pytest.approx(
                ds.options[name].energy_pj, rel=0.02), (gemm, name)
            assert dv.options[name].time_ns == pytest.approx(
                ds.options[name].time_ns, rel=0.02), (gemm, name)


def test_plan_workload_backends_agree():
    gemms = PAPER_GEMMS
    dv = plan_workload(gemms, CONFIGS, backend="vectorized")
    ds = plan_workload(gemms, CONFIGS, backend="scalar")
    for a, b in zip(dv, ds):
        assert a.use_cim == b.use_cim
        assert (a.best_energy == b.best_energy
                or _tie_ok(a.best_energy, b.best_energy, b.options,
                           b.baseline))


def test_smem_config_batch_matches_scalar():
    """The vectorized model's new CiM@SMEM scoring (configA/B) matches
    cost_model.evaluate."""
    for g in (GEMM(512, 1024, 1024), GEMM(1, 4096, 4096),
              GEMM(128, 5632, 2048)):
        for name in ("Digital-6T@SMEM-A", "Digital-6T@SMEM-B",
                     "Analog-8T@SMEM-B"):
            cfg = CONFIGS[name]
            m_s = evaluate(g, cfg)
            m_v = SweepEngine().cim_metrics([(g, cfg)])[0]
            assert m_v.energy_pj == pytest.approx(m_s.energy_pj, rel=0.02)
            assert m_v.time_ns == pytest.approx(m_s.time_ns, rel=0.02)


def test_baseline_batch_matches_scalar():
    """The vectorized model's new tensor-core baseline scoring matches
    baseline.evaluate_baseline."""
    eng = SweepEngine()
    for g in PAPER_GEMMS:
        m_s = evaluate_baseline(g)
        m_v = eng.baseline_metrics([g])[0]
        assert m_v.energy_pj == pytest.approx(m_s.energy_pj, rel=0.02), g
        assert m_v.time_ns == pytest.approx(m_s.time_ns, rel=0.02), g


def test_sweep_cache_hits_and_identity():
    eng = SweepEngine()
    g = GEMM(256, 512, 512)
    cfg = CONFIGS["Digital-6T@RF"]
    m1 = eng.cim_metrics([(g, cfg)])[0]
    assert eng.cache_info()["misses"] == 1
    m2 = eng.cim_metrics([(g, cfg)])[0]
    assert m2 is m1                       # cached object, no re-evaluation
    assert eng.cache_info()["hits"] == 1
    # label/count do not affect metrics: same cache entry
    m3 = eng.cim_metrics([(g.scaled(label="x", count=7), cfg)])[0]
    assert m3 is m1
    # eviction respects the LRU bound
    small = SweepEngine(cache_size=2)
    for m in (16, 32, 64, 128):
        small.baseline_metrics([GEMM(m, 64, 64)])
    assert small.cache_info()["size"] == 2


def test_jit_cache_clear_preserves_results():
    # benchmarks drop the compiled kernels to take an honest cold-jit
    # sample; recompiling must reproduce identical metrics
    from repro.core.sweep import jit_cache_clear
    eng = SweepEngine()
    g = GEMM(64, 128, 128)
    cfg = CONFIGS["Digital-6T@RF"]
    before = eng.cim_metrics([(g, cfg)])[0]
    jit_cache_clear()
    eng.cache_clear()
    after = eng.cim_metrics([(g, cfg)])[0]
    assert after.energy_pj == before.energy_pj
    assert after.time_ns == before.time_ns


def test_unknown_backend_rejected():
    g = GEMM(64, 64, 64)
    with pytest.raises(ValueError, match="unknown planner backend"):
        decide(g, backend="vectorised")
    with pytest.raises(ValueError, match="unknown planner backend"):
        plan_workload([g], backend="batched")


def test_order_mode_greedy_falls_back_to_scalar():
    g = GEMM(256, 512, 512)
    d = decide(g, CONFIGS, order_mode="greedy", backend="vectorized")
    ds = decide(g, CONFIGS, order_mode="greedy", backend="scalar")
    assert d.best_energy == ds.best_energy
    with pytest.raises(ValueError):
        SweepEngine().cim_metrics([(g, CONFIGS["Digital-6T@RF"])],
                                  order_mode="greedy")


def _fake_metrics(energy, time):
    return metrics_from_row(1000.0, {"energy_pj": energy, "time_ns": time})


def test_summarize_uses_eligible_winner():
    """energy_gain_x must come from the option decide() deploys, not from
    an unconstrained min-energy config the throughput floor rules out."""
    g = GEMM(64, 64, 64)
    base = _fake_metrics(energy=100.0, time=10.0)          # 100 gflops eq.
    options = {
        # eligible winner: keeps throughput, halves energy
        "good": _fake_metrics(energy=50.0, time=12.0),
        # ineligible tempter: 10x energy win but 100x throughput collapse
        "slow": _fake_metrics(energy=10.0, time=1000.0),
    }
    d = make_decision(g, base, options, throughput_floor=0.5)
    assert d.best_energy == "good"
    s = summarize([d])
    assert s["energy_gain_x"] == pytest.approx(100.0 / 50.0)


def test_make_decision_shared_by_both_backends():
    g = GEMM(512, 1024, 1024)
    ds = decide(g, CONFIGS, backend="scalar")
    rebuilt = make_decision(g, ds.baseline, ds.options)
    assert rebuilt.best_energy == ds.best_energy
    assert rebuilt.use_cim == ds.use_cim


def test_serving_kernel_plan_gates_decode_gemvs():
    """ServeSession consults the batched planner: per-token decode GEMMs
    of a tiny model are "don't CiM" (the paper's M=1 pathology)."""
    from repro.configs import ARCHS, RunConfig, reduced
    from repro.models import init
    from repro.serving import ServeSession
    import jax

    cfg = reduced(ARCHS["qwen2-7b"])
    rc = RunConfig(remat=False, attn_impl="naive")
    params = init(jax.random.PRNGKey(0), cfg)
    s = ServeSession(cfg, rc, params, max_len=32, batch=2)
    plan = s.kernel_plan
    assert plan and all(isinstance(d, Decision) for d in plan.values())
    assert s.kernel_plan is plan          # lazily computed once
    # batch-2 decode: every GEMM is tiny/low-reuse -> nothing offloads
    gemvs = [lab for lab in plan if "decode" in lab or "Wq" in lab]
    assert gemvs
    for lab in gemvs:
        assert s.use_cim_for(lab) == plan[lab].use_cim
    assert not s.use_cim_for("no-such-gemm")
