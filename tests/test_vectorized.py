"""Vectorized cost model vs the scalar reference (property: the on-device
batch evaluation of a mapping matches cost_model.evaluate_cim)."""
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DIGITAL_6T, ANALOG_6T, GEMM, CiMSystemConfig, evaluate
from repro.core.cost_model import evaluate_cim
from repro.core.mapping import candidate_mappings
from repro.core.vectorized import evaluate_batch, exhaustive_best

CFG = CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF")
small = st.sampled_from([16, 64, 256, 512, 1024, 4096])


@given(m=small, n=small, k=small)
@settings(max_examples=20, deadline=None)
def test_batch_matches_scalar_model(m, n, k):
    g = GEMM(m, n, k)
    maps = candidate_mappings(g, CFG)
    batch = {f: jnp.asarray([getattr(mp, f) for mp in maps], jnp.int32)
             for f in ("k_arr", "n_arr", "pk", "pn", "m1", "fk", "fn")}
    out = evaluate_batch(g, CFG, batch)
    for i, mp in enumerate(maps):
        ref = evaluate_cim(mp, order_mode="exact")
        assert bool(out["valid"][i])
        assert float(out["energy_pj"][i]) == pytest.approx(
            ref.energy_pj, rel=0.02)
        assert float(out["time_ns"][i]) == pytest.approx(
            ref.time_ns, rel=0.02)


@pytest.mark.slow
def test_exhaustive_never_loses_to_priority_mapper():
    """The on-device exhaustive search lower-bounds the priority mapper —
    and the mapper should be within 25% of the global optimum (the
    paper's claim that its priorities capture the reuse structure)."""
    for g in (GEMM(512, 1024, 1024), GEMM(256, 256, 256),
              GEMM(1, 4096, 4096)):
        best, best_map, n_points = exhaustive_best(g, CFG)
        ours = evaluate(g, CFG)
        assert best["energy_pj"] <= ours.energy_pj * 1.001
        # the priority mapper captures the reuse structure to within ~1.6x
        # of the global optimum (quantified optimality gap — see
        # EXPERIMENTS.md §What/When/Where; the paper could not enumerate)
        assert ours.energy_pj <= best["energy_pj"] * 1.6, \
            (g, ours.energy_pj, best)
        assert n_points > 1000


def test_batch_invalid_maps_masked():
    g = GEMM(64, 64, 64)
    batch = {"k_arr": jnp.asarray([1 << 14]), "n_arr": jnp.asarray([16]),
             "pk": jnp.asarray([1]), "pn": jnp.asarray([1]),
             "m1": jnp.asarray([1]), "fk": jnp.asarray([1]),
             "fn": jnp.asarray([1])}
    out = evaluate_batch(g, CFG, batch)
    assert not bool(out["valid"][0])
    assert float(out["tops_per_w"][0]) == 0.0
