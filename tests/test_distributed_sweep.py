"""Multi-host distributed sweep engine: parity, streaming, telemetry.

The fast tier covers everything that runs in one process: the streaming
chunk enumerator (bitwise parity against the whole-batch path, group
splitting across tiles, telemetry accounting), `launch.distributed`'s
init/env plumbing, and the report-layer rendering of the new telemetry
blocks.

The @slow test is the acceptance gate modeled on PR 2's 4-device
subprocess test: it spawns 2 real OS processes that initialize
`jax.distributed` over localhost (env-var driven, CPU gloo collectives),
build ONE global row mesh spanning both processes' devices, and plan the
full 1338-row golden workload grid through the chunked distributed
engine.  Both processes must reproduce tests/golden/planner_verdicts.csv
bitwise — the same fingerprint the single-process backends are pinned to
— with the grid forced through >= 2 streaming chunks.
"""
import csv
import json
import os
import socket
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import GEMM
from repro.core.planner import standard_configs
from repro.core.sweep import SweepEngine, _iter_chunks
from repro.launch import distributed as dist

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CONFIGS = standard_configs()
GEMMS = [GEMM(512, 1024, 1024), GEMM(1, 4096, 4096), GEMM(17, 100, 300)]


# --- streaming chunk enumerator (single process) ---------------------------


def test_chunked_engine_bitwise_parity():
    """chunk_rows bounds every device call without changing a single bit:
    rows are elementwise and the per-group reductions keep first-index
    tie-breaks across tiles.  chunk_rows=7 is deliberately awkward — it
    splits candidate-mapping groups mid-group and leaves ragged tails."""
    eu = SweepEngine(mesh=None)
    ec = SweepEngine(mesh=None, chunk_rows=7)
    pairs = [(g, CONFIGS[n]) for g in GEMMS
             for n in ("Digital-6T@RF", "Digital-6T@SMEM-B",
                       "Analog-8T@SMEM-A")]
    for om in ("exact", "greedy"):
        for a, b in zip(eu.cim_metrics(pairs, om),
                        ec.cim_metrics(pairs, om)):
            assert a.energy_pj == b.energy_pj     # bitwise, not approx
            assert a.time_ns == b.time_ns
            assert a.dram_bytes == b.dram_bytes
    for a, b in zip(eu.baseline_metrics(GEMMS[:2]),
                    ec.baseline_metrics(GEMMS[:2])):
        assert a.energy_pj == b.energy_pj
        assert a.time_ns == b.time_ns
    info = ec.cache_info()
    assert info["chunks"]["chunk_rows"] == 7
    assert info["chunks"]["evaluated"] >= 2       # grid really streamed
    assert info["chunks"]["rows"] > 0
    assert info["distributed"] is None            # single-host mesh


def test_iter_chunks_segments_cover_groups_exactly():
    """Every group row lands in exactly one tile segment, in order, and
    group offsets let a consumer reassemble per-group indices."""
    groups = [("a", {"x": np.arange(5.0)}),
              ("b", {"x": np.arange(100.0, 103.0)}),
              ("c", {"x": np.arange(200.0, 212.0)})]
    seen: dict = {}
    for batch, segs in _iter_chunks(iter(groups), chunk_rows=4):
        n = len(batch["x"])
        assert n <= 4
        for gid, off, lo, hi in segs:
            assert 0 <= lo < hi <= n
            seen.setdefault(gid, []).extend(
                (off + j, batch["x"][lo + j]) for j in range(hi - lo))
    for gid, cols in groups:
        idx, vals = zip(*seen[gid])
        assert list(idx) == list(range(len(cols["x"])))      # no gaps
        assert np.array_equal(np.asarray(vals), cols["x"])
    # chunk_rows=None degenerates to one tile holding everything
    tiles = list(_iter_chunks(iter(groups), chunk_rows=None))
    assert len(tiles) == 1 and len(tiles[0][0]["x"]) == 20


def test_chunk_rows_validation_and_cache_clear_resets_accounting():
    with pytest.raises(ValueError, match="chunk_rows"):
        SweepEngine(mesh=None, chunk_rows=0)
    eng = SweepEngine(mesh=None, chunk_rows=8)
    eng.cim_metrics([(GEMMS[0], CONFIGS["Digital-6T@RF"])])
    assert eng.cache_info()["chunks"]["evaluated"] >= 1
    eng.cache_clear()
    c = eng.cache_info()["chunks"]
    assert c["evaluated"] == c["rows"] == c["padded_rows"] == 0
    assert c["chunk_rows"] == 8                   # config survives clear


# --- launch.distributed plumbing (single process) --------------------------


def test_initialize_is_noop_when_unconfigured(monkeypatch):
    for var in (dist.ENV_COORDINATOR, dist.ENV_NUM_PROCESSES,
                dist.ENV_PROCESS_ID):
        monkeypatch.delenv(var, raising=False)
    assert dist.initialize() is False
    assert dist.is_initialized() is False


def test_initialize_rejects_partial_configuration(monkeypatch):
    monkeypatch.setenv(dist.ENV_COORDINATOR, "127.0.0.1:1")
    monkeypatch.delenv(dist.ENV_NUM_PROCESSES, raising=False)
    monkeypatch.delenv(dist.ENV_PROCESS_ID, raising=False)
    with pytest.raises(ValueError, match="num_processes/process_id"):
        dist.initialize()


def test_multihost_detection_and_shard_balance():
    from repro.launch.mesh import row_mesh
    mesh = row_mesh(jax.devices()[:1])
    assert dist.is_multihost(None) is False
    assert dist.is_multihost(mesh) is False       # all devices local
    assert dist.shard_balance(8, mesh) == {"0": 8}
    info = dist.distributed_info()
    assert info["processes"] == 1
    assert info["global_devices"] >= info["local_devices"] >= 1


def test_global_row_mesh_spans_all_devices():
    mesh = dist.global_row_mesh()
    assert mesh.size == jax.device_count()
    assert mesh.axis_names == ("rows",)


def test_host_local_to_global_round_trip():
    """On a single-host mesh the global-array builder is an exact
    identity: per-device slices reassemble to the input columns.  (The
    cross-host case is exercised end to end by the @slow subprocess
    test.)"""
    from repro.launch.mesh import row_mesh
    mesh = row_mesh(jax.devices()[:1])
    batch = {"a": np.arange(8, dtype=np.float32),
             "b": np.arange(8, 16, dtype=np.float32)}
    gb = dist.host_local_to_global(batch, mesh)
    for k, v in batch.items():
        assert np.array_equal(np.asarray(gb[k]), v)
        assert gb[k].sharding.mesh.size == 1


# --- report rendering ------------------------------------------------------


def _cell(engine_cache: dict) -> dict:
    return {"status": "ok", "arch": "a", "shape": "s", "mesh": "single",
            "planner": {"summary": {"cim_fraction": 0.5,
                                    "energy_gain_x": 2.0},
                        "plan_hits": 3, "plan_misses": 4,
                        "cache": engine_cache}}


def test_report_renders_chunk_and_shard_telemetry():
    """launch.report: the planner-cache table appends the streaming-tile
    accounting, and shard_balance_table renders the per-host cache + row
    balance of distributed cells (skipping single-host/legacy cells)."""
    from repro.launch.report import planner_cache_table, shard_balance_table
    distributed = {"processes": 2, "process_index": 0,
                   "global_devices": 2, "local_devices": 1,
                   "mesh_devices": 2,
                   "shard_balance": {"0": 2304, "1": 2304}}
    cache = {"hits": 7, "misses": 9, "size": 16,
             "chunks": {"chunk_rows": 512, "evaluated": 9,
                        "rows": 4403, "padded_rows": 205},
             "distributed": distributed}
    table = planner_cache_table([_cell(cache)])
    assert "chunks=9@512rows" in table
    balance = shard_balance_table([_cell(cache)])
    assert "p0/2" in balance and "p0:2304 p1:2304" in balance
    assert "7h/9m" in balance
    # single-host cells (distributed None) and legacy cells (no chunks
    # field at all) render without the new columns and without crashing
    legacy = {"hits": 1, "misses": 2, "size": 3}
    assert "size=3" in planner_cache_table([_cell(legacy)])
    assert "no distributed sweep telemetry" in shard_balance_table(
        [_cell(legacy), _cell({**cache, "distributed": None})])


# --- the multi-process acceptance gate -------------------------------------


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.mark.slow
def test_distributed_engine_matches_golden_fingerprint(tmp_path):
    """2 OS processes x jax.distributed x global row mesh x streaming
    chunks reproduce the single-process golden verdict fingerprint
    bitwise (tests/golden/planner_verdicts.csv — the full widened
    arch x shape/phase x precision grid), on every host."""
    nproc = 2
    out_base = str(tmp_path / "worker_out.json")
    env = dict(os.environ)
    env.update({
        "PYTHONPATH": os.path.join(REPO, "src"),
        "JAX_PLATFORMS": "cpu",
        dist.ENV_COORDINATOR: f"127.0.0.1:{_free_port()}",
        dist.ENV_NUM_PROCESSES: str(nproc),
        "WORKER_OUT": out_base,
        "WORKER_CHUNK_ROWS": "512",   # 1338-GEMM grid => >= 2 chunks/kind
    })
    worker = os.path.join(REPO, "tests", "_distributed_worker.py")
    procs = []
    try:
        for i in range(nproc):
            penv = dict(env)
            penv[dist.ENV_PROCESS_ID] = str(i)
            procs.append(subprocess.Popen(
                [sys.executable, worker], env=penv, cwd=REPO,
                stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
        outs = [p.communicate(timeout=540) for p in procs]
        for p, (so, se) in zip(procs, outs):
            assert p.returncode == 0, f"worker failed:\n{se[-2000:]}"
            assert "WORKER-OK" in so
    finally:
        # a hung worker (e.g. initialize() blocking on a runner without
        # CPU collectives) must not leak past the test: TimeoutExpired
        # or a mid-loop assert would otherwise leave both processes
        # alive holding the coordinator port
        for p in procs:
            if p.poll() is None:
                p.kill()
                p.wait()

    with open(os.path.join(REPO, "tests", "golden",
                           "planner_verdicts.csv")) as f:
        golden = list(csv.DictReader(f))
    payloads = []
    for i in range(nproc):
        with open(f"{out_base}.{i}") as f:
            payloads.append(json.load(f))
    for pay in payloads:
        assert pay["processes"] == nproc
        assert pay["global_devices"] >= nproc     # mesh spans both hosts
        assert pay["local_devices"] < pay["global_devices"]
        # the grid really streamed: >= 2 chunks, rows accounted for
        assert pay["chunks"]["evaluated"] >= 2
        assert pay["chunks"]["rows"] > 512
        d = pay["distributed"]
        assert d is not None and d["processes"] == nproc
        # shard balance covers every process and sums to the padded rows
        assert set(d["shard_balance"]) == {str(j) for j in range(nproc)}
        assert (sum(d["shard_balance"].values())
                == pay["chunks"]["rows"] + pay["chunks"]["padded_rows"])
        # THE gate: bitwise golden fingerprint, every field of every row
        assert len(pay["rows"]) == len(golden) == 1338
        for want, have in zip(golden, pay["rows"]):
            assert want == have, (want, have)
    # SPMD: both hosts computed the identical plan
    assert payloads[0]["rows"] == payloads[1]["rows"]
