"""Docs can't rot: docstring, snippet-exec, and link-integrity gates.

Three regression surfaces, all cheap enough for tier-1 (CI also runs
them in the dedicated `docs` job):

* every public module under src/repro/ must carry a non-trivial
  docstring — docs/architecture.md points readers at module docstrings
  as the authoritative per-box reference, so an empty one is a doc bug;
* every ```python fenced block in docs/*.md is extracted and exec'd
  from the repo root (append ``noexec`` to the info string for
  illustrative snippets that need external state, e.g. a multi-host
  pod);
* every markdown link in docs/*.md and README.md resolves: repo-local
  paths must exist, intra-repo #anchors must match a real heading
  (http(s) links are recorded but NOT fetched — no network in CI).
"""
import importlib
import os
import pkgutil
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")
DOC_FILES = sorted(
    os.path.join(DOCS, f) for f in os.listdir(DOCS) if f.endswith(".md"))
LINKED_FILES = DOC_FILES + [os.path.join(REPO, "README.md")]

MIN_DOCSTRING = 40     # chars: one real sentence, not a placeholder


def _public_modules() -> list[str]:
    import repro
    names = ["repro"]
    for m in pkgutil.walk_packages(repro.__path__, "repro."):
        if not any(part.startswith("_") for part in m.name.split(".")[1:]):
            names.append(m.name)
    return names


@pytest.mark.parametrize("name", _public_modules())
def test_public_module_has_nontrivial_docstring(name):
    """docs/architecture.md delegates per-module detail to docstrings;
    this keeps that promise honest."""
    if name == "repro.launch.dryrun":
        # importing dryrun pins XLA_FLAGS=...device_count=512 (see its
        # module NOTE) — read the docstring from source instead
        import ast
        path = os.path.join(REPO, "src", *name.split(".")) + ".py"
        with open(path) as f:
            doc = ast.get_docstring(ast.parse(f.read()))
    else:
        doc = importlib.import_module(name).__doc__
    assert doc and len(doc.strip()) >= MIN_DOCSTRING, (
        f"{name} has no (or a trivial) module docstring — document the "
        f"module or it falls out of the architecture guide")


# --- doc snippets -----------------------------------------------------------


def _python_snippets():
    """(doc, index, code) for every executable ```python block."""
    out = []
    fence = re.compile(r"^```(\S+)([^\n]*)\n(.*?)^```\s*$",
                       re.MULTILINE | re.DOTALL)
    for path in DOC_FILES:
        with open(path) as f:
            text = f.read()
        n = 0
        for m in fence.finditer(text):
            lang, info, code = m.group(1), m.group(2), m.group(3)
            if lang != "python":
                continue
            n += 1
            if "noexec" in info:
                continue
            out.append((os.path.basename(path), n, code))
    return out


SNIPPETS = _python_snippets()


def test_docs_contain_executable_snippets():
    """The extractor really found code (an empty list would make the
    exec test below pass vacuously)."""
    assert len(SNIPPETS) >= 4
    assert {doc for doc, _, _ in SNIPPETS} >= {
        "architecture.md", "sweep-backends.md",
        "reproducing-paper-figures.md", "serving.md",
        "adaptive-planning.md", "campaigns.md"}


@pytest.mark.parametrize("doc,idx,code",
                         SNIPPETS,
                         ids=[f"{d}#{i}" for d, i, _ in SNIPPETS])
def test_doc_snippet_executes(doc, idx, code, monkeypatch):
    """Doctest-style: every ```python block in docs/ must run as-is from
    the repo root (mark genuinely non-runnable examples ``noexec``)."""
    monkeypatch.chdir(REPO)
    namespace = {"__name__": f"docsnippet_{doc}_{idx}"}
    exec(compile(code, f"{doc}#snippet{idx}", "exec"), namespace)


# --- links ------------------------------------------------------------------


def _github_anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (enough of it for our docs)."""
    h = heading.strip().lower()
    h = re.sub(r"[`*_]", "", h)
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors_of(path: str) -> set:
    anchors = set()
    in_fence = False
    with open(path) as f:
        for line in f:
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
            elif not in_fence and line.startswith("#"):
                anchors.add(_github_anchor(line.lstrip("#")))
    return anchors


def test_markdown_links_resolve():
    """Internal anchors + repo-relative paths only; no network."""
    link_re = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
    errors = []
    for path in LINKED_FILES:
        base = os.path.dirname(path)
        rel = os.path.relpath(path, REPO)
        with open(path) as f:
            text = f.read()
        # fenced code often contains [x](y)-looking noise — strip it
        text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
        for target in link_re.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            dest, _, anchor = target.partition("#")
            dest_path = os.path.normpath(os.path.join(base, dest)) \
                if dest else path
            if not os.path.exists(dest_path):
                errors.append(f"{rel}: broken path {target!r}")
                continue
            if anchor and dest_path.endswith(".md"):
                if anchor not in _anchors_of(dest_path):
                    errors.append(f"{rel}: missing anchor {target!r}")
    assert not errors, "\n".join(errors)


def test_readme_links_the_docs_tree():
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for doc in ("docs/architecture.md", "docs/sweep-backends.md",
                "docs/reproducing-paper-figures.md", "docs/serving.md",
                "docs/adaptive-planning.md", "docs/campaigns.md"):
        assert doc in readme, f"README does not link {doc}"
