"""CiM primitive model (paper §IV-A, Table IV) + technology scaling (eqs 2-5).

A CiM *primitive* is one SRAM array modified for in-situ MACs.  The paper's
dataflow-centric representation exposes it as Rp×Cp parallel *CiM units*,
each of which serially performs Rh×Ch MAC operations.  Hence the array holds
a weight tile of (Rp·Rh) K-rows × (Cp·Ch) N-columns, and one full-array
activation ("wave") takes `latency_ns` and computes up to
Rp·Cp·Rh·Ch MACs.
"""
from __future__ import annotations

import dataclasses

# Stillmaker & Baas 45nm energy-model coefficients (paper footnote 1).
A45 = (1.103, -0.362, 0.2767)


def tech_scale_ratio(v_ref: float, a_ref: tuple[float, float, float] = A45,
                     v_45: float = 1.0) -> float:
    """Paper eqs. (3)-(5): T_ratio = f_45nm / f_ref.

    f(V) = a_e2·V² + a_e1·V + a_e0 evaluated at the reference design's supply
    voltage with its node coefficients, vs 45 nm at 1 V.
    """
    f45 = A45[0] * v_45 ** 2 + A45[1] * v_45 + A45[2]
    fref = a_ref[0] * v_ref ** 2 + a_ref[1] * v_ref + a_ref[2]
    return f45 / fref


def mac_energy_pj_from_tops_w(tops_per_w: float, v_ref: float = 1.0,
                              a_ref: tuple[float, float, float] = A45) -> float:
    """Paper eq. (2): pJ/MAC = (2 / TOPS/W) · T_ratio.

    (2 ops per MAC; TOPS/W is reported in ops.)
    """
    return (2.0 / tops_per_w) * tech_scale_ratio(v_ref, a_ref)


def compute_latency_ns(cim_freq_ghz: float, cycles_mac: float) -> float:
    """Paper eq. (6): latency normalized to a 1 GHz system clock."""
    return (1.0 / cim_freq_ghz) * cycles_mac


# --- per-precision macro scaling (What-axis widening) ----------------------
# Multiplicative factors on the Table-IV 8b-8b calibration point, following
# the analog/digital SRAM-CiM characterizations (SRAM-CiM review, CiMLoop):
#
#   * analog INT-b: MAC energy splits into an array part that scales
#     linearly with the bit-serial input width (0.4·b/8) and an ADC part
#     that scales with resolution (0.6·2^(b-8)); activation latency is
#     dominated by input DAC streaming (0.5 + 0.5·b/8); halving the
#     weight width doubles usable column parallelism (colpar 8/b — two
#     INT4 weights share one 8b column's ADC range).
#   * analog FP8: shared-exponent handling costs an extra alignment pass
#     (energy x1.3, latency x1.5) and halves column parallelism (0.5).
#   * digital INT-b: bit-serial multiply — energy (b/8)^2, latency b/8,
#     no column-parallelism change.
#   * digital FP8: exponent-align adder overhead (energy x1.2, latency
#     x1.25), full column parallelism.
#
# All four branches are exactly (1, 1, 1) at INT8 so the Table-IV
# calibration (tests/test_calibration.py) is untouched.

ANALOG_FP8_FACTORS = (1.3, 1.5, 0.5)
DIGITAL_FP8_FACTORS = (1.2, 1.25, 1.0)
SUPPORTED_BITS = (4, 8)


def precision_factors(compute_type: str, bits: int,
                      fp: bool = False) -> tuple[float, float, float]:
    """(energy_x, latency_x, colpar_x) vs the INT8 calibration point.

    energy_x scales the per-MAC energy, latency_x the per-step array
    activation latency, and colpar_x the usable column parallelism
    (Cp_eff = Cp * colpar_x).  Identity at INT8 by construction.
    """
    if compute_type not in ("analog", "digital"):
        raise ValueError(f"unknown compute_type {compute_type!r}")
    if fp:
        if bits != 8:
            raise ValueError(f"FP precision requires 8 bits, got {bits}")
        return (ANALOG_FP8_FACTORS if compute_type == "analog"
                else DIGITAL_FP8_FACTORS)
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported integer precision INT{bits} "
                         f"(supported: {SUPPORTED_BITS})")
    if bits == 8:
        return (1.0, 1.0, 1.0)
    r = bits / 8.0
    if compute_type == "analog":
        return (0.4 * r + 0.6 * 2.0 ** (bits - 8), 0.5 + 0.5 * r, 8.0 / bits)
    return (r * r, r, 1.0)


@dataclasses.dataclass(frozen=True)
class CiMPrimitive:
    """One CiM array (paper Table IV row)."""

    name: str
    compute_type: str           # "analog" | "digital"
    cell: str                   # "6T" | "8T"
    Rp: int                     # parallel rows (CiM units along K)
    Cp: int                     # parallel cols (CiM units along N)
    Rh: int                     # row hold: serial MACs along K per unit
    Ch: int                     # col hold: serial MACs along N per unit
    capacity_bytes: int         # SRAM capacity (4 KB for all prototypes)
    latency_ns: float           # full-array activation latency (Table IV)
    mac_energy_pj: float        # 8b-8b MAC energy, scaled to 45nm/1V
    area_overhead: float        # × vs iso-capacity plain SRAM

    # --- geometry ---------------------------------------------------------
    @property
    def k_rows(self) -> int:
        """K-extent of the stationary weight tile held by one array."""
        return self.Rp * self.Rh

    @property
    def n_cols(self) -> int:
        """N-extent of the stationary weight tile held by one array."""
        return self.Cp * self.Ch

    @property
    def weight_elems(self) -> int:
        """INT8 weights held stationary by one array."""
        return min(self.k_rows * self.n_cols, self.capacity_bytes)

    @property
    def mac_units(self) -> int:
        """Total MAC positions (utilization denominator): Rp·Cp units of
        Rh·Ch MACs each (paper §V-D)."""
        return self.Rp * self.Cp * self.Rh * self.Ch

    @property
    def macs_per_wave(self) -> int:
        """MACs performed by one full-array activation."""
        return self.mac_units

    @property
    def peak_gops(self) -> float:
        """Appendix B: 2·Rp·Cp·Rh·Ch / latency for one array, in GOPS."""
        return 2.0 * self.mac_units / self.latency_ns

    def __str__(self) -> str:  # pragma: no cover
        return (f"{self.name}(Rp={self.Rp},Cp={self.Cp},Rh={self.Rh},"
                f"Ch={self.Ch},{self.latency_ns}ns,{self.mac_energy_pj}pJ)")


# --- the four prototypes of Table IV --------------------------------------

ANALOG_6T = CiMPrimitive(
    name="Analog-6T", compute_type="analog", cell="6T",
    Rp=64, Cp=4, Rh=1, Ch=16, capacity_bytes=4096,
    latency_ns=9.0, mac_energy_pj=0.15, area_overhead=1.34)

ANALOG_8T = CiMPrimitive(
    name="Analog-8T", compute_type="analog", cell="8T",
    Rp=64, Cp=4, Rh=1, Ch=16, capacity_bytes=4096,
    latency_ns=144.0, mac_energy_pj=0.09, area_overhead=2.1)

DIGITAL_6T = CiMPrimitive(
    name="Digital-6T", compute_type="digital", cell="6T",
    Rp=256, Cp=16, Rh=1, Ch=1, capacity_bytes=4096,
    latency_ns=18.0, mac_energy_pj=0.34, area_overhead=1.4)

DIGITAL_8T = CiMPrimitive(
    name="Digital-8T", compute_type="digital", cell="8T",
    Rp=1, Cp=128, Rh=10, Ch=1, capacity_bytes=4096,
    latency_ns=233.0, mac_energy_pj=0.84, area_overhead=1.1)

PRIMITIVES: dict[str, CiMPrimitive] = {
    p.name: p for p in (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T)
}
# Short aliases used in the appendix figures.
PRIMITIVES["A-1"] = ANALOG_6T
PRIMITIVES["A-2"] = ANALOG_8T
PRIMITIVES["D-1"] = DIGITAL_6T
PRIMITIVES["D-2"] = DIGITAL_8T


@dataclasses.dataclass(frozen=True)
class TensorCoreSpec:
    """Baseline tensor-core-like SM (paper §V-A).

    4 sub-cores × 16×16 PEs, INT8, 1 GHz.  MAC energy 0.26 pJ (Table III),
    PE-buffer operand access 0.02 pJ.
    """

    subcores: int = 4
    pe_rows: int = 16
    pe_cols: int = 16
    mac_energy_pj: float = 0.26
    pe_buffer_energy_pj: float = 0.02
    freq_ghz: float = 1.0

    @property
    def macs_per_cycle(self) -> int:
        return self.subcores * self.pe_rows * self.pe_cols

    @property
    def peak_gops(self) -> float:
        return 2.0 * self.macs_per_cycle * self.freq_ghz


TENSOR_CORE = TensorCoreSpec()
