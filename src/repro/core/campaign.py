"""Design-space campaigns: streaming Pareto-frontier exploration.

The planner answers one question per (GEMM, config); the paper's real
product is the *map* — energy/throughput/area frontiers across CiM
prototype, cache level, and workload.  This module turns the batched
sweep engine into that map at scale:

  * `CampaignSpec` enumerates a design grid **lazily** — CiM prototype
    x cache level x primitive-budget scale x input-driver serialization
    x K:N balance threshold (the mapping-config axes) x DRAM order mode
    x precision x workload GEMM.  Grids of 100k+ points are walked as a
    generator; nothing materializes the cross product.
  * `run_campaign` streams the points in bounded blocks through
    `SweepEngine.cim_metrics`; an engine built with `chunk_rows=N`
    additionally bounds every *device* batch (and a multi-host mesh
    spreads the rows pod-wide) — peak memory is O(block + chunk +
    front), never O(grid).
  * Declarative **constraint contracts** (`Constraint`, e.g.
    "time_ns<=2e6" — a latency budget per decode step — or
    "area_bytes<=1e5" — an SRAM macro area cap) filter candidates
    before front reduction and are carried into the result's provenance.
  * Survivors reduce to multi-objective Pareto fronts over
    (energy_pj, time_ns, area_bytes) with the vectorized dominance
    kernel + cross-chunk merging of `repro.core.pareto`, grouped either
    per workload cell (objectives aggregated over the cell's GEMMs,
    count-weighted — "which design for this model/phase") or per GEMM
    ("which design for this shape").
  * `certify_point` / `certify_front` re-evaluate a chosen front row
    from scratch **through the planner** (`plan_workload_batched` on a
    fresh engine) and assert the recorded objectives reproduce bitwise
    and the contracts still hold — the deployment gate for a design
    picked off a frontier CSV.

Precision is a first-class What axis: `precisions` accepts the tokens
4 / 8 / "fp8" (normalized by `parse_precision` to canonical
"int4"/"int8"/"fp8"), flowing into `GEMM.bits`/`GEMM.fp` and from
there into the per-precision CiM cost factors
(`primitives.precision_factors`: analog ADC/DAC scaling + column
parallelism, digital bit-serial latency).  INT8 remains the Table-IV
calibration identity.

`launch.campaign` is the CLI; tests/test_campaign_golden.py pins a
~1k-point grid's frontier CSV for both batched backends, and
benchmarks/campaign_bench.py gates byte-identical determinism in CI.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator, NamedTuple, Sequence

import numpy as np

from ..configs import ARCHS, SHAPES
from .gemm import GEMM
from .llm_workloads import gemms_of_model
from .loopnest import check_order_mode
from .memory import RF, CiMSystemConfig, configb_count, \
    iso_area_primitive_count
from .pareto import ParetoAccumulator, pareto_mask_np
from .primitives import PRIMITIVES, SUPPORTED_BITS
from .sweep import SweepEngine, plan_workload_batched

# The campaign's objective triple, all minimized.
OBJECTIVES = ("energy_pj", "time_ns", "area_bytes")

# Cache-level axis values: RF iso-area, SMEM at the RF count (configA),
# SMEM at 16x (configB) — planner.standard_configs' three integration
# points, here scaled by the primitive-budget axis.
CIM_LEVELS = ("RF", "SMEM-A", "SMEM-B")

GROUP_MODES = ("workload", "gemm")

# Metrics a constraint contract may bound (workload-mode rows carry the
# count-weighted aggregates, gemm-mode rows the per-GEMM values).
CONSTRAINT_METRICS = ("energy_pj", "time_ns", "area_bytes", "gflops",
                     "tops_per_w")

FRONT_FIELDS = ("group", "index", "label", "M", "N", "K", "precision",
                "prototype", "cim_level", "scale", "serialize",
                "kn_threshold", "order_mode", "config", "n_prims",
                "n_gemms", "energy_pj", "time_ns", "area_bytes",
                "gflops", "tops_per_w")


def parse_precision(token) -> tuple[int, bool, str]:
    """Normalize one precision-axis token to (bits, fp, canonical name).

    Accepts ints (4, 8) and strings ("4", "8", "int4", "int8", "fp8");
    the canonical names ("int4" / "int8" / "fp8") are what front CSVs
    carry in their `precision` column."""
    t = str(token).strip().lower()
    if t in ("fp8", "float8", "f8"):
        return 8, True, "fp8"
    if t.startswith("int"):
        t = t[3:]
    try:
        bits = int(t)
    except ValueError:
        raise ValueError(f"unknown precision token {token!r}: expected "
                         f"one of {SUPPORTED_BITS} or 'fp8'") from None
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"unsupported integer precision INT{bits} "
                         f"(supported: {SUPPORTED_BITS}, plus 'fp8')")
    return bits, False, f"int{bits}"


def area_proxy_bytes(cfg: CiMSystemConfig) -> float:
    """SRAM macro area proxy of one config: primitive count x capacity x
    the prototype's area overhead vs plain SRAM (paper Table IV), in
    iso-capacity byte-equivalents.  The third campaign objective — the
    silicon budget a frontier point spends for its energy/latency."""
    p = cfg.prim
    return float(cfg.resolved_n_prims() * p.capacity_bytes
                 * p.area_overhead)


def build_config(prototype: str, level: str, scale: float = 1.0,
                 serialize: bool = True,
                 kn_threshold: int = 4) -> CiMSystemConfig:
    """One grid config: `prototype` at `level` with `scale` x the
    level's iso-area primitive budget (SMEM-B scales the 16x configB
    count), the given input-driver serialization, and the mapping
    algorithm's K:N balance threshold."""
    if prototype not in PRIMITIVES:
        raise ValueError(f"unknown CiM prototype {prototype!r}; expected "
                         f"one of {sorted(PRIMITIVES)}")
    if level not in CIM_LEVELS:
        raise ValueError(f"unknown cache level {level!r}; expected one "
                         f"of {CIM_LEVELS}")
    if not scale > 0:
        raise ValueError(f"scale must be > 0, got {scale}")
    prim = PRIMITIVES[prototype]
    base = (configb_count(prim) if level == "SMEM-B"
            else iso_area_primitive_count(RF, prim))
    n = max(1, int(round(scale * base)))
    return CiMSystemConfig(
        prim=prim, cim_level="RF" if level == "RF" else "SMEM",
        n_prims=n, serialize_primitives=serialize,
        kn_balance_threshold=kn_threshold)


def config_label(prototype: str, level: str, scale: float,
                 serialize: bool, kn_threshold: int) -> str:
    return (f"{prototype}@{level}:x{scale:g}:"
            f"{'ser' if serialize else 'par'}:kn{kn_threshold}")


@dataclasses.dataclass(frozen=True)
class Constraint:
    """One declarative constraint contract: `metric op bound`.

    metric: one of CONSTRAINT_METRICS; op: "<=" or ">=".  Contracts
    filter candidate rows *before* front reduction (`run_campaign`) and
    are re-asserted by the certification gate on freshly re-evaluated
    metrics (`certify_point`)."""

    metric: str
    op: str
    bound: float

    def __post_init__(self):
        if self.metric not in CONSTRAINT_METRICS:
            raise ValueError(f"unknown constraint metric {self.metric!r};"
                             f" expected one of {CONSTRAINT_METRICS}")
        if self.op not in ("<=", ">="):
            raise ValueError(f"unknown constraint op {self.op!r}; "
                             f"expected '<=' or '>='")
        if not np.isfinite(self.bound):
            raise ValueError(f"constraint bound must be finite, "
                             f"got {self.bound}")

    @classmethod
    def parse(cls, text: str) -> "Constraint":
        """Parse "metric<=bound" / "metric>=bound" (the CLI syntax)."""
        for op in ("<=", ">="):
            if op in text:
                metric, _, bound = text.partition(op)
                try:
                    return cls(metric.strip(), op, float(bound))
                except ValueError as e:
                    # non-numeric bound or unknown metric: re-raise with
                    # the original text for a self-describing CLI error
                    raise ValueError(
                        f"bad constraint {text!r}: {e}") from e
        raise ValueError(f"bad constraint {text!r}: expected "
                         f"'metric<=bound' or 'metric>=bound'")

    def spec(self) -> str:
        return f"{self.metric}{self.op}{self.bound:g}"

    def check(self, value: float) -> bool:
        return value <= self.bound if self.op == "<=" \
            else value >= self.bound

    def mask(self, cols: dict) -> np.ndarray:
        """(n,) bool over columnar metric arrays."""
        v = np.asarray(cols[self.metric], np.float64)
        return v <= self.bound if self.op == "<=" else v >= self.bound


class CampaignUnit(NamedTuple):
    """One design-axis combination (everything but the workload GEMM).

    `precision` is the canonical token ("int4"/"int8"/"fp8");
    `bits`/`fp` are the parsed element-format pair applied to the
    workload GEMMs."""
    unit_index: int
    precision: str
    prototype: str
    level: str
    scale: float
    serialize: bool
    kn_threshold: int
    order_mode: str
    config: str                  # label
    cfg: CiMSystemConfig
    area_bytes: float
    bits: int = 8
    fp: bool = False


class CampaignPoint(NamedTuple):
    """One grid point: a workload GEMM under one design unit."""
    index: int                   # global grid-enumeration index
    group: str                   # "arch/shape"
    group_key: tuple             # (workload_idx, gemm_idx) — gemm mode
    gemm: GEMM
    unit: CampaignUnit


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """Declarative grid: the cross product of every axis below, per
    workload GEMM.  Enumeration (`iter_points`) is lazy and
    deterministic — workload-major, GEMM-major, design-unit-minor —
    and the enumeration index is each point's canonical identity (front
    CSVs sort by it, which is what makes output independent of block
    and chunk boundaries)."""

    workloads: tuple[tuple[str, str], ...] = (
        ("mistral-nemo-12b", "decode_32k"),)
    prototypes: tuple[str, ...] = ("Analog-6T", "Analog-8T",
                                   "Digital-6T", "Digital-8T")
    levels: tuple[str, ...] = CIM_LEVELS
    scales: tuple[float, ...] = (1.0,)
    serialize_modes: tuple[bool, ...] = (True,)
    kn_thresholds: tuple[int, ...] = (4,)
    order_modes: tuple[str, ...] = ("exact",)
    # precision-axis tokens: 4 / 8 / "fp8" (see parse_precision)
    precisions: tuple = (8,)

    def __post_init__(self):
        if not self.workloads:
            raise ValueError("spec needs at least one workload cell")
        for arch, shape in self.workloads:
            if arch not in ARCHS:
                raise ValueError(f"unknown arch {arch!r}; expected one "
                                 f"of {sorted(ARCHS)}")
            if shape not in SHAPES:
                raise ValueError(f"unknown shape {shape!r}; expected "
                                 f"one of {sorted(SHAPES)}")
        for om in self.order_modes:
            check_order_mode(om)
        for p in self.precisions:
            parse_precision(p)       # raises on unknown tokens
        # axis validation via build_config (raises on bad values)
        for proto in self.prototypes:
            for level in self.levels:
                for s in self.scales:
                    build_config(proto, level, s)

    def units(self) -> list[CampaignUnit]:
        """The per-GEMM design-axis combinations, in enumeration order
        (precision-major ... order-mode-minor).

        The input-driver serialization axis only differentiates
        RF-level configs — it is a no-op in the cost model at SMEM — so
        non-RF levels take the first serialize mode only, keeping the
        grid free of duplicate points (duplicates are exact objective
        ties and would all land on the front together)."""
        out: list[CampaignUnit] = []
        for prec in self.precisions:
            bits, fp, tok = parse_precision(prec)
            for proto in self.prototypes:
                for level in self.levels:
                    for scale in self.scales:
                        sers = self.serialize_modes if level == "RF" \
                            else self.serialize_modes[:1]
                        for ser in sers:
                            for kn in self.kn_thresholds:
                                cfg = build_config(proto, level, scale,
                                                   ser, kn)
                                for om in self.order_modes:
                                    out.append(CampaignUnit(
                                        len(out), tok, proto,
                                        level, float(scale), bool(ser),
                                        int(kn), om,
                                        config_label(proto, level,
                                                     scale, ser, kn),
                                        cfg, area_proxy_bytes(cfg),
                                        bits, fp))
        return out

    def workload_gemms(self) -> list[tuple[str, list[GEMM]]]:
        """[(group name, GEMMs)] per workload cell — small (hundreds of
        GEMMs), unlike the full grid."""
        return [(f"{arch}/{shape}",
                 gemms_of_model(ARCHS[arch], SHAPES[shape]))
                for arch, shape in self.workloads]

    @property
    def n_units(self) -> int:
        n_rf = sum(1 for lv in self.levels if lv == "RF")
        n_other = len(self.levels) - n_rf
        per_level = (n_rf * len(self.serialize_modes)
                     + n_other * min(1, len(self.serialize_modes)))
        return (len(self.precisions) * len(self.prototypes) * per_level
                * len(self.scales) * len(self.kn_thresholds)
                * len(self.order_modes))

    @property
    def n_points(self) -> int:
        n_gemms = sum(len(gs) for _, gs in self.workload_gemms())
        return n_gemms * self.n_units

    def iter_points(self) -> Iterator[CampaignPoint]:
        """Lazy grid walk — the only full-grid traversal anywhere; no
        list of all points ever exists."""
        units = self.units()
        index = 0
        for wi, (group, gemms) in enumerate(self.workload_gemms()):
            for gi, g in enumerate(gemms):
                for u in units:
                    gemm = g if (g.bits == u.bits and g.fp == u.fp) \
                        else g.scaled(bits=u.bits, fp=u.fp)
                    yield CampaignPoint(index, group, (wi, gi), gemm, u)
                    index += 1

    def describe(self) -> dict:
        """Provenance block: every axis plus the grid digest (reports
        and bench artifacts embed it, so a frontier CSV names the exact
        grid that produced it)."""
        return {
            "workloads": [list(w) for w in self.workloads],
            "prototypes": list(self.prototypes),
            "levels": list(self.levels),
            "scales": list(self.scales),
            "serialize_modes": list(self.serialize_modes),
            "kn_thresholds": list(self.kn_thresholds),
            "order_modes": list(self.order_modes),
            "precisions": list(self.precisions),
            "n_units": self.n_units,
            "n_points": self.n_points,
            "digest": self.digest(),
        }

    def digest(self) -> str:
        """Stable sha256 of the grid axes (not the evaluations)."""
        d = dataclasses.asdict(self)
        text = repr(sorted(d.items()))
        return hashlib.sha256(text.encode()).hexdigest()[:16]


def _fmt(v) -> str:
    """Deterministic CSV cell formatting: full-precision repr for
    floats (the objectives are float32-exact values — repr round-trips
    them bitwise), plain str otherwise."""
    if isinstance(v, float):
        return repr(v)
    return str(v)


def _unit_cells(u: CampaignUnit) -> dict:
    return {"precision": u.precision, "prototype": u.prototype,
            "cim_level": u.level, "scale": u.scale,
            "serialize": int(u.serialize),
            "kn_threshold": u.kn_threshold, "order_mode": u.order_mode,
            "config": u.config,
            "n_prims": u.cfg.resolved_n_prims()}


@dataclasses.dataclass
class CampaignResult:
    """Fronts + accounting of one campaign run.

    `front` rows are dicts over FRONT_FIELDS, already in canonical order
    (group enumeration order, then point/unit index); `csv_text()` is
    byte-deterministic — the golden test and the bench determinism gate
    compare it verbatim."""

    spec: CampaignSpec
    group_by: str
    backend: str
    contracts: tuple[Constraint, ...]
    front: list[dict]
    stats: dict

    def csv_text(self) -> str:
        lines = [",".join(FRONT_FIELDS)]
        for row in self.front:
            lines.append(",".join(_fmt(row[f]) for f in FRONT_FIELDS))
        return "\n".join(lines) + "\n"

    def write_csv(self, path: str) -> str:
        text = self.csv_text()
        with open(path, "w", newline="") as f:
            f.write(text)
        return hashlib.sha256(text.encode()).hexdigest()

    def report(self) -> dict:
        return {
            "group_by": self.group_by,
            "backend": self.backend,
            "contracts": [c.spec() for c in self.contracts],
            "front_rows": len(self.front),
            "spec": self.spec.describe(),
            "stats": self.stats,
        }


def _metric_cols(mets, units) -> dict:
    """Columnar per-point metrics for constraint masks + objectives."""
    return {
        "energy_pj": np.asarray([m.energy_pj for m in mets], np.float64),
        "time_ns": np.asarray([m.time_ns for m in mets], np.float64),
        "area_bytes": np.asarray([u.area_bytes for u in units],
                                 np.float64),
        "gflops": np.asarray([m.gflops for m in mets], np.float64),
        "tops_per_w": np.asarray([m.tops_per_w for m in mets],
                                 np.float64),
    }


def run_campaign(spec: CampaignSpec,
                 contracts: Sequence[Constraint] = (),
                 engine: SweepEngine | None = None,
                 backend: str = "vectorized",
                 block_points: int = 4096,
                 group_by: str = "workload") -> CampaignResult:
    """Stream the grid through the sweep engine and reduce to fronts.

    Points are buffered in blocks of at most `block_points` and
    evaluated via `engine.cim_metrics` (an engine constructed with
    `chunk_rows=N` further tiles each device call — pass one to bound
    device memory; the default engine here streams 4096-row chunks).
    Rows failing any constraint contract are dropped before reduction
    and counted per contract in `stats`.

    group_by="workload": objectives are count-weighted sums over each
    workload cell's GEMMs per design unit — one front per cell over the
    design units ("which design for this model/phase").
    group_by="gemm": one front per workload GEMM over the design units,
    folded incrementally through `ParetoAccumulator` as blocks complete
    (a GEMM's units routinely span block boundaries — this is the
    cross-chunk merge path).
    """
    if group_by not in GROUP_MODES:
        raise ValueError(f"unknown group_by {group_by!r}; expected one "
                         f"of {GROUP_MODES}")
    if block_points < 1:
        raise ValueError(f"block_points must be >= 1, "
                         f"got {block_points}")
    contracts = tuple(contracts)
    engine = engine or SweepEngine(chunk_rows=4096)

    n_invalid = 0
    filtered = {c.spec(): 0 for c in contracts}
    points_evaluated = 0

    # group_by="gemm" state: one accumulator + surviving-row meta per
    # GEMM, pruned as rows fall off the front (memory stays O(fronts))
    accs: dict[tuple, ParetoAccumulator] = {}
    metas: dict[tuple, dict[int, dict]] = {}
    group_names: dict[tuple, str] = {}
    # group_by="workload" state: count-weighted running sums per
    # (group, unit) — O(groups x units), grid-size independent
    agg: dict[tuple[int, int], list] = {}

    def eval_block(block: list[CampaignPoint]) -> list:
        """Metrics for a block, point order preserved (cim_metrics takes
        one order_mode per call, so split/reassemble by order mode)."""
        mets: list = [None] * len(block)
        for om in spec.order_modes:
            ix = [i for i, p in enumerate(block)
                  if p.unit.order_mode == om]
            if not ix:
                continue
            got = engine.cim_metrics(
                [(block[i].gemm, block[i].unit.cfg) for i in ix],
                om, backend)
            for i, m in zip(ix, got):
                mets[i] = m
        return mets

    def fold_block(block: list[CampaignPoint]) -> None:
        nonlocal n_invalid, points_evaluated
        mets = eval_block(block)
        points_evaluated += len(block)
        units = [p.unit for p in block]
        cols = _metric_cols(mets, units)
        ok = np.isfinite(cols["energy_pj"]) & np.isfinite(cols["time_ns"])
        n_invalid += int((~ok).sum())

        if group_by == "workload":
            # contracts apply to the *aggregated* rows later; here just
            # fold the per-point sums
            for p, m, valid in zip(block, mets, ok):
                wi = p.group_key[0]
                st = agg.get((wi, p.unit.unit_index))
                if st is None:
                    st = [0.0, 0.0, 0.0, 0, True, p.unit]
                    agg[(wi, p.unit.unit_index)] = st
                c = p.gemm.count
                st[0] += m.energy_pj * c
                st[1] += m.time_ns * c
                st[2] += m.ops * c
                st[3] += 1
                st[4] = st[4] and bool(valid)
            return

        # group_by="gemm": constraint-filter, then stream into the
        # per-GEMM accumulators
        keep = ok.copy()
        for c in contracts:
            m = c.mask(cols)
            filtered[c.spec()] += int((keep & ~m).sum())
            keep &= m
        by_group: dict[tuple, list[int]] = {}
        for i, p in enumerate(block):
            if keep[i]:
                by_group.setdefault(p.group_key, []).append(i)
            group_names.setdefault(p.group_key, p.group)
        for gk, ix in by_group.items():
            acc = accs.get(gk)
            if acc is None:
                acc = accs[gk] = ParetoAccumulator(len(OBJECTIVES))
                metas[gk] = {}
            pts = np.stack([[cols["energy_pj"][i], cols["time_ns"][i],
                             cols["area_bytes"][i]] for i in ix]
                           ).astype(np.float32)
            idx = [block[i].index for i in ix]
            acc.update(pts, idx)
            meta = metas[gk]
            for i in ix:
                p, m, u = block[i], mets[i], block[i].unit
                meta[p.index] = {
                    "group": p.group, "index": p.index,
                    "label": p.gemm.label, "M": p.gemm.M, "N": p.gemm.N,
                    "K": p.gemm.K, **_unit_cells(u), "n_gemms": 1,
                    "energy_pj": m.energy_pj, "time_ns": m.time_ns,
                    "area_bytes": u.area_bytes, "gflops": m.gflops,
                    "tops_per_w": m.tops_per_w,
                }
            live = set(int(i) for i in acc.front()[1])
            metas[gk] = {i: r for i, r in meta.items() if i in live}

    block: list[CampaignPoint] = []
    for point in spec.iter_points():
        block.append(point)
        if len(block) >= block_points:
            fold_block(block)
            block = []
    if block:
        fold_block(block)

    units = spec.units()
    front_rows: list[dict] = []
    n_groups = 0

    if group_by == "workload":
        wg = spec.workload_gemms()
        for wi, (group, gemms) in enumerate(wg):
            rows = []
            for u in units:
                st = agg.get((wi, u.unit_index))
                if st is None or not st[4]:
                    if st is not None:
                        n_invalid += 0   # gemm-level invalids counted
                    continue
                e, t, ops, n_g = st[0], st[1], st[2], st[3]
                rows.append({
                    "group": group, "index": u.unit_index, "label": "",
                    "M": "", "N": "", "K": "", **_unit_cells(u),
                    "n_gemms": n_g, "energy_pj": e, "time_ns": t,
                    "area_bytes": u.area_bytes,
                    "gflops": ops / t if t else 0.0,
                    "tops_per_w": ops / e if e else 0.0,
                })
            if not rows:
                continue
            n_groups += 1
            cols = {m: np.asarray([r[m] for r in rows], np.float64)
                    for m in CONSTRAINT_METRICS}
            keep = np.ones(len(rows), bool)
            for c in contracts:
                m = c.mask(cols)
                filtered[c.spec()] += int((keep & ~m).sum())
                keep &= m
            rows = [r for r, k in zip(rows, keep) if k]
            if not rows:
                continue
            pts = np.asarray([[r[o] for o in OBJECTIVES] for r in rows],
                             np.float32)
            mask = pareto_mask_np(pts)
            front_rows += [r for r, k in zip(rows, mask) if k]
    else:
        for gk in sorted(accs):
            _, idx = accs[gk].front()
            n_groups += 1
            front_rows += [metas[gk][int(i)] for i in idx]

    stats = {
        "n_points": spec.n_points,
        "points_evaluated": points_evaluated,
        "n_invalid": n_invalid,
        "constraint_filtered": filtered,
        "n_groups": n_groups,
        "front_rows": len(front_rows),
        "engine_chunks": engine.cache_info()["chunks"],
    }
    return CampaignResult(spec=spec, group_by=group_by, backend=backend,
                          contracts=contracts, front=front_rows,
                          stats=stats)


# --- certification gate ------------------------------------------------------


def _row_gemms(row: dict, spec: CampaignSpec) -> list[GEMM]:
    """The GEMMs behind one front row: the single GEMM of a gemm-mode
    row, or the whole workload cell of a workload-mode row."""
    arch, _, shape = row["group"].partition("/")
    bits, fp, _ = parse_precision(row["precision"])
    if row["label"] != "" and row["M"] != "":
        return [GEMM(int(row["M"]), int(row["N"]), int(row["K"]),
                     bits=bits, fp=fp, label=row["label"])]
    gemms = gemms_of_model(ARCHS[arch], SHAPES[shape])
    return [g if (g.bits == bits and g.fp == fp)
            else g.scaled(bits=bits, fp=fp) for g in gemms]


def certify_point(row: dict,
                  contracts: Sequence[Constraint] = (),
                  backend: str = "vectorized",
                  engine: SweepEngine | None = None) -> dict:
    """Re-evaluate one front row from scratch and gate it for deployment.

    The row's GEMMs run through the planner (`plan_workload_batched`)
    on a *fresh* engine — no shared LRU, so the recorded objectives are
    genuinely recomputed — and the gate asserts (a) the re-aggregated
    energy/time reproduce the row **bitwise** (the sweep kernels are
    deterministic; any difference means the cost model or grid drifted
    since the campaign ran) and (b) every constraint contract still
    holds on the recomputed metrics.  The planner block reports how
    many of the row's GEMMs the when-rule would actually deploy on this
    config, plus `planner.summarize` over the contract-passing subset —
    which can be empty, in which case summarize's empty-input
    ValueError is recorded instead of an all-zero aggregate.
    """
    u_cfg = build_config(row["prototype"], row["cim_level"],
                         float(row["scale"]), bool(int(row["serialize"])),
                         int(row["kn_threshold"]))
    area = area_proxy_bytes(u_cfg)
    label = row["config"]
    gemms = _row_gemms(row, CampaignSpec())
    engine = engine or SweepEngine(mesh=None)
    decisions = plan_workload_batched(
        gemms, configs={label: u_cfg}, order_mode=row["order_mode"],
        engine=engine, backend=backend)

    energy = time = ops = 0.0
    per_gemm_pass: list[bool] = []
    for d in decisions:
        m = d.options[label]
        energy += m.energy_pj * d.gemm.count
        time += m.time_ns * d.gemm.count
        ops += m.ops * d.gemm.count
        cols = {"energy_pj": m.energy_pj, "time_ns": m.time_ns,
                "area_bytes": area, "gflops": m.gflops,
                "tops_per_w": m.tops_per_w}
        per_gemm_pass.append(all(c.check(cols[c.metric])
                                 for c in contracts))

    recomputed = {"energy_pj": energy, "time_ns": time,
                  "area_bytes": area,
                  "gflops": ops / time if time else 0.0,
                  "tops_per_w": ops / energy if energy else 0.0}
    recorded = {k: float(row[k]) for k in recomputed}
    bitwise_ok = all(recomputed[k] == recorded[k] for k in recomputed)

    checks = [{"constraint": c.spec(),
               "ok": bool(c.check(recomputed[c.metric]))}
              for c in contracts]
    contracts_ok = all(c["ok"] for c in checks)

    from .planner import summarize
    passing = [d for d, ok in zip(decisions, per_gemm_pass) if ok]
    summary_err = None
    try:
        summary = summarize(passing)
    except ValueError as e:
        # every GEMM of this row fails some contract — report the
        # condition instead of an all-zero aggregate
        summary, summary_err = None, str(e)

    return {
        "group": row["group"], "config": label,
        "order_mode": row["order_mode"],
        "n_gemms": len(gemms),
        "bitwise_ok": bitwise_ok,
        "recorded": recorded,
        "recomputed": recomputed,
        "contracts": checks,
        "contracts_ok": contracts_ok,
        "certified": bitwise_ok and contracts_ok,
        "planner": {
            "n_use_cim": sum(d.use_cim for d in decisions),
            "contract_passing_gemms": len(passing),
            "filtered_summary": summary,
            "filtered_summary_error": summary_err,
        },
    }


def certify_front(result: CampaignResult,
                  objectives: Sequence[str] = ("energy_pj",),
                  backend: str | None = None,
                  max_groups: int | None = None) -> dict:
    """Certify each group's champion row per objective (the min row —
    the design point a user would pick off the frontier).  One fresh
    engine is shared across the certifications so repeated baselines
    are swept once.  Returns per-point reports + an overall `ok` (an
    empty front certifies nothing and is not ok)."""
    for o in objectives:
        if o not in CONSTRAINT_METRICS:
            raise ValueError(f"unknown certification objective {o!r}; "
                             f"expected one of {CONSTRAINT_METRICS}")
    backend = backend or result.backend
    groups: dict[str, list[dict]] = {}
    for row in result.front:
        groups.setdefault(row["group"], []).append(row)
    names = list(groups)
    if max_groups is not None:
        names = names[:max_groups]
    engine = SweepEngine(mesh=None)
    points, seen = [], set()
    for name in names:
        for obj in objectives:
            row = min(groups[name], key=lambda r: float(r[obj]))
            key = (name, row["index"], row.get("label", ""))
            if key in seen:
                continue
            seen.add(key)
            points.append(certify_point(row, result.contracts, backend,
                                        engine))
    return {
        "objectives": list(objectives),
        "groups_certified": len(names),
        "points": points,
        "ok": bool(points) and all(p["certified"] for p in points),
    }
