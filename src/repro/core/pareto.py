"""Multi-objective Pareto reduction for design-space campaigns.

The campaign layer (repro.core.campaign) scores 100k+-point design grids
and keeps only the interesting ones: the non-dominated frontier over
(energy, latency, area proxy).  This module provides the reduction in
three layers, pinned to each other by the property-based suite in
tests/test_pareto_properties.py:

  * `dominates(a, b)` / `pareto_mask_ref(points)` — the scalar O(n²)
    reference semantics.  `a` dominates `b` iff a <= b on every
    objective and a < b on at least one (all objectives minimized).
    Exact ties dominate in neither direction, so duplicate points stay
    on the front together — which is what makes the front, as a set,
    invariant under row permutation.
  * `pareto_mask(points)` — the same predicate as one vectorized,
    jit-compatible kernel: an (n, d) objective matrix in, an (n,) keep
    mask out, all pairs compared by broadcast.  `pareto_mask_np` is the
    host entry point: it pads to a power of two with +inf rows (bounding
    jit retraces to O(log n), like the sweep engine) and runs the jitted
    kernel; +inf padding rows can never dominate a row with any finite
    objective, so the real rows' verdicts are unaffected.
  * `ParetoAccumulator` — cross-chunk front merging.  Campaign grids
    stream through the sweep engine chunk by chunk; the accumulator
    folds each chunk's survivors into a running front using the identity
    pareto(A ∪ B) == pareto(pareto(A) ∪ pareto(B)), so host memory holds
    O(front + chunk) rows, never the whole grid.  `front()` returns the
    rows sorted by their caller-assigned global index — the front is a
    set, so index-sorted emission makes the output byte-identical no
    matter how the stream was cut into chunks (the golden campaign CSV
    depends on this).

All comparisons happen in float32 — the dtype the sweep backends emit —
so the vectorized kernel, the reference, and the accumulator agree
bitwise.  Rows with non-finite objectives (invalid mappings get +inf
energy/time) should be filtered out before reduction; campaign.py does.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def dominates(a, b) -> bool:
    """Scalar reference: does point `a` dominate point `b`?

    True iff a <= b on every objective and a < b on at least one (all
    objectives minimized).  Irreflexive by construction: a point never
    dominates itself, and exact duplicates dominate in neither
    direction."""
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return bool(np.all(a <= b) and np.any(a < b))


def pareto_mask_ref(points) -> np.ndarray:
    """O(n²) reference front mask: keep[j] iff no row dominates row j.

    The brute-force semantics the vectorized kernel is property-tested
    against (tests/test_pareto_properties.py asserts bitwise equality,
    ties and degenerate single-point sets included)."""
    pts = np.asarray(points, np.float32)
    n = pts.shape[0]
    keep = np.ones(n, bool)
    for j in range(n):
        for i in range(n):
            if i != j and dominates(pts[i], pts[j]):
                keep[j] = False
                break
    return keep


def pareto_mask(points):
    """Vectorized, jit-compatible front mask over an (n, d) objective
    matrix (all objectives minimized): returns an (n,) bool array, True
    for non-dominated rows.

    One broadcastized all-pairs comparison — le[i, j] is "i <= j on
    every objective", lt[i, j] is "i < j on at least one" — so row j is
    dominated iff any i has both.  O(n²d) work and O(n²) memory: callers
    reducing large streams tile the input (`ParetoAccumulator`) instead
    of growing n."""
    pts = jnp.asarray(points, jnp.float32)
    le = jnp.all(pts[:, None, :] <= pts[None, :, :], axis=-1)
    lt = jnp.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    return ~jnp.any(le & lt, axis=0)


_MASK_JIT = jax.jit(pareto_mask)


def _pad_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def pareto_mask_np(points) -> np.ndarray:
    """Host entry point for the jitted kernel: pad the (n, d) matrix to
    the next power of two with +inf rows (bounds compiled variants to
    O(log n) shapes), run `pareto_mask`, slice the real rows back.

    An all-+inf pad row is <= a real row only where that row is also
    +inf and is never strictly < it there, so padding cannot change any
    real row's verdict."""
    pts = np.asarray(points, np.float32)
    if pts.ndim != 2:
        raise ValueError(f"points must be (n, d), got shape {pts.shape}")
    n = pts.shape[0]
    if n == 0:
        return np.zeros(0, bool)
    m = _pad_pow2(n)
    if m != n:
        pts = np.concatenate(
            [pts, np.full((m - n, pts.shape[1]), np.inf, np.float32)])
    return np.asarray(_MASK_JIT(pts))[:n]


class ParetoAccumulator:
    """Streaming front reduction with cross-chunk merging.

    Feed chunks of (points, indices) in any order and any cut; the
    accumulator keeps only the running non-dominated set, so memory is
    bounded by O(front + chunk) rows.  Correctness rests on
    pareto(A ∪ B) == pareto(pareto(A) ∪ pareto(B)): each update reduces
    the incoming chunk, concatenates it with the running front, and
    re-reduces the union.

    `indices` are caller-assigned global identifiers (the campaign uses
    the point's grid-enumeration index); `front()` emits the surviving
    rows sorted by index, which makes the result independent of chunk
    placement byte for byte — the property suite asserts equality with
    the whole-batch `pareto_mask_np` under random splits and row
    permutations.
    """

    def __init__(self, n_objectives: int):
        if n_objectives < 1:
            raise ValueError(
                f"n_objectives must be >= 1, got {n_objectives}")
        self.n_objectives = n_objectives
        self._points = np.zeros((0, n_objectives), np.float32)
        self._indices = np.zeros(0, np.int64)
        self.rows_seen = 0
        self.chunks_merged = 0

    def update(self, points, indices) -> None:
        """Fold one chunk of candidate rows into the running front."""
        pts = np.asarray(points, np.float32)
        idx = np.asarray(indices, np.int64)
        if pts.ndim != 2 or pts.shape[1] != self.n_objectives:
            raise ValueError(
                f"expected (n, {self.n_objectives}) points, "
                f"got shape {pts.shape}")
        if idx.shape != (pts.shape[0],):
            raise ValueError(
                f"indices shape {idx.shape} does not match "
                f"{pts.shape[0]} points")
        if not np.isfinite(pts).all():
            raise ValueError(
                "non-finite objectives reached the front reduction — "
                "filter invalid rows before accumulating")
        self.rows_seen += pts.shape[0]
        self.chunks_merged += 1
        if pts.shape[0] == 0:
            return
        keep = pareto_mask_np(pts)               # reduce the chunk first
        cat = np.concatenate([self._points, pts[keep]])
        cat_idx = np.concatenate([self._indices, idx[keep]])
        keep = pareto_mask_np(cat)               # then the union
        self._points = cat[keep]
        self._indices = cat_idx[keep]

    def __len__(self) -> int:
        return int(self._points.shape[0])

    def front(self) -> tuple[np.ndarray, np.ndarray]:
        """(points, indices) of the current front, sorted by index
        ascending — the canonical emission order (chunk-placement- and
        permutation-independent, since the front itself is a set)."""
        order = np.argsort(self._indices, kind="stable")
        return self._points[order], self._indices[order]
