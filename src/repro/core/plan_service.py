"""Shape-bucketed plan service: online What/When/Where under live traffic.

The paper's verdict depends on the GEMM shape, and under live traffic the
shapes are not static: the N dimension of every decode GEMM moves with the
active-slot count (ragged batches joining and leaving) and the positions
grow token by token.  A `KernelPlanTable` frozen once at session build
time is therefore stale the moment occupancy changes — the batch-1 vs
batch-1024 asymmetry is exactly the paper's "when" axis.

This module makes the planner a *service* beside the model server:

  * `BucketLattice` quantizes an incoming decode operating point
    (active-slot count, max position) onto a small grid of buckets —
    each bucket edge is the representative shape its plan is computed
    at, and lookups snap *up* to the nearest edge so a bucket's plan is
    always computed at a shape at least as large as any point it serves;
  * `PlanService` answers `lookup(n_active, max_pos)` with that bucket's
    versioned `KernelPlanTable`.  Plans are built through the batched
    sweep backends (`planner.plan_workload`, so the thread-safe
    `SweepEngine` LRU makes repeat bucket builds nearly free), memoized
    per bucket, and — with `refresh_every=N` — re-planned after every N
    lookups, either synchronously or on a background thread
    (`background=True`): serving never blocks on a refresh, it keeps the
    previous table until the new one lands.  A refresh whose table
    differs from the cached one is a **verdict flip**; the serving layer
    (`repro.serving.ContinuousBatchingEngine`) hot-swaps between
    already-compiled decode executables when it observes one.

Telemetry (`telemetry()`): per-bucket hit/miss/build/flip counters,
build latencies, table digests, and the service-wide lookup hit rate —
the numbers `launch.report` renders and `benchmarks/serve_adaptive_bench`
gates.  `plan_fn` is injectable so tests and the benchmark can force
deterministic verdict flips without faking traffic shapes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

from ..configs.base import ModelConfig, ShapeConfig
from ..quant import KernelPlanTable


def _pow2_edges(top: int) -> tuple[int, ...]:
    """1, 2, 4, ... capped at (and always including) `top`."""
    edges, e = [], 1
    while e < top:
        edges.append(e)
        e *= 2
    edges.append(top)
    return tuple(edges)


@dataclasses.dataclass(frozen=True)
class BucketLattice:
    """The bucket grid: ascending active-slot and max-position edges.

    A point (n_active, max_pos) maps to the smallest edge >= it on each
    axis (points beyond the top edge clamp to it), so every bucket's
    representative shape dominates the points it serves — the plan is
    never computed at a smaller GEMM than the one being decoded."""
    batch_edges: tuple[int, ...]
    len_edges: tuple[int, ...]

    def __post_init__(self):
        for name, edges in (("batch_edges", self.batch_edges),
                            ("len_edges", self.len_edges)):
            if not edges:
                raise ValueError(f"{name} must not be empty")
            if any(e < 1 for e in edges):
                raise ValueError(f"{name} must be positive, got {edges}")
            if any(a >= b for a, b in zip(edges, edges[1:])):
                raise ValueError(
                    f"{name} must be strictly ascending, got {edges}")

    @classmethod
    def for_engine(cls, n_slots: int, max_len: int) -> "BucketLattice":
        """Power-of-two edges covering an engine's slot/length geometry —
        the default lattice `launch.serve --adaptive` builds."""
        return cls(_pow2_edges(max(1, n_slots)),
                   _pow2_edges(max(1, max_len)))

    @classmethod
    def parse(cls, spec: str) -> "BucketLattice":
        """Parse a `--bucket-edges` CLI spec: "b1,b2,..:l1,l2,.."
        (batch edges, then length edges, colon-separated)."""
        try:
            b_part, l_part = spec.split(":")
            batch = tuple(int(x) for x in b_part.split(",") if x)
            lens = tuple(int(x) for x in l_part.split(",") if x)
        except ValueError:
            raise ValueError(
                f"bad bucket-edges spec {spec!r}: expected "
                f"'b1,b2,..:l1,l2,..' (e.g. '1,2,4:64,256')") from None
        return cls(batch, lens)

    @property
    def n_buckets(self) -> int:
        return len(self.batch_edges) * len(self.len_edges)

    @staticmethod
    def _snap_up(edges: tuple[int, ...], v: int) -> int:
        for e in edges:
            if v <= e:
                return e
        return edges[-1]

    def bucket_of(self, n_active: int, max_pos: int) -> tuple[int, int]:
        """The (batch_edge, len_edge) bucket serving this operating
        point.  max_pos is the deepest active position (0 for a batch of
        fresh slots) — it snaps as a *length*, i.e. max_pos + 1."""
        return (self._snap_up(self.batch_edges, max(1, n_active)),
                self._snap_up(self.len_edges, max(1, max_pos + 1)))


class _BucketRecord:
    """Mutable per-bucket state (guarded by the service lock)."""

    def __init__(self):
        self.table: KernelPlanTable | None = None
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.flips = 0
        self.flipped_labels: tuple[str, ...] = ()
        self.age = 0              # lookups since the table was (re)built
        self.refreshing = False   # a refresh is in flight
        self.last_build_s: float | None = None


class PlanService:
    """Shape-bucketed verdict server: bucket -> versioned KernelPlanTable.

    `lookup(n_active, max_pos)` quantizes the operating point onto the
    lattice and returns `(bucket, table)`.  A bucket's first lookup
    builds its plan synchronously (there is nothing to serve yet);
    afterwards lookups are dictionary hits, and every `refresh_every`
    hits the bucket is re-planned — on a daemon thread when
    `background=True` (the default: serving keeps the stale table until
    the fresh one lands) or inline otherwise (deterministic, what tests
    and the benchmark use).  A refresh that changes the table counts as
    a verdict flip and records the flipped labels.

    plan_fn(shape) -> list[planner.Decision] defaults to the batched
    sweep planner over `gemms_of_model(cfg, shape)`; inject it to force
    deterministic flips or stub the planner.
    """

    def __init__(self, cfg: ModelConfig, lattice: BucketLattice,
                 refresh_every: int = 0, backend: str = "vectorized",
                 plan_fn: Callable | None = None, background: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        if refresh_every < 0:
            raise ValueError(
                f"refresh_every must be >= 0 (0 = never), "
                f"got {refresh_every}")
        self.cfg = cfg
        self.lattice = lattice
        self.refresh_every = refresh_every
        self.backend = backend
        self.background = background
        self.clock = clock
        self._plan_fn = plan_fn or self._default_plan_fn
        self._lock = threading.Lock()     # stats + table installs
        self._build_lock = threading.Lock()  # serializes first builds
        self._buckets: dict[tuple[int, int], _BucketRecord] = {}
        self._threads: list[threading.Thread] = []

    # --- planning ---------------------------------------------------------

    def plan_shape(self, bucket: tuple[int, int]) -> ShapeConfig:
        """The representative decode shape a bucket's plan is computed
        at: batch = the bucket's slot edge, seq_len = its length edge."""
        b, l = bucket
        return ShapeConfig(f"bucket-b{b}-l{l}", l, b, "decode")

    def _default_plan_fn(self, shape: ShapeConfig):
        from .llm_workloads import gemms_of_model
        from .planner import plan_workload
        return plan_workload(gemms_of_model(self.cfg, shape),
                             backend=self.backend)

    def _build(self, bucket: tuple[int, int]
               ) -> tuple[KernelPlanTable, float]:
        t0 = self.clock()
        decisions = self._plan_fn(self.plan_shape(bucket))
        table = KernelPlanTable.from_decisions(decisions,
                                               model_name=self.cfg.name)
        return table, self.clock() - t0

    def _refresh(self, bucket: tuple[int, int]) -> None:
        """Re-plan one bucket and install the result; a changed table is
        a verdict flip (flipped labels recorded for telemetry)."""
        table, dt = self._build(bucket)
        with self._lock:
            rec = self._buckets[bucket]
            old = rec.table
            rec.table = table
            rec.builds += 1
            rec.last_build_s = dt
            rec.age = 0
            rec.refreshing = False
            if old is not None and old != table:
                rec.flips += 1
                rec.flipped_labels = old.flips(table)

    # --- the serving-side API ---------------------------------------------

    def lookup(self, n_active: int, max_pos: int
               ) -> tuple[tuple[int, int], KernelPlanTable]:
        """(bucket, table) for one decode operating point.  First lookup
        of a bucket builds its plan synchronously; later lookups serve
        the memoized table, scheduling a refresh every `refresh_every`
        hits (background or inline per the service mode)."""
        bucket = self.lattice.bucket_of(n_active, max_pos)
        refresh_due = False
        with self._lock:
            rec = self._buckets.setdefault(bucket, _BucketRecord())
            if rec.table is None:
                rec.misses += 1
            else:
                rec.hits += 1
                rec.age += 1
                if (self.refresh_every
                        and rec.age >= self.refresh_every
                        and not rec.refreshing):
                    rec.refreshing = True
                    refresh_due = True
        if rec.table is None:
            # cold bucket: nothing to serve yet, so the build is
            # synchronous (serialized so concurrent cold lookups of one
            # bucket plan it once)
            with self._build_lock:
                if rec.table is None:
                    self._refresh(bucket)
        elif refresh_due:
            if self.background:
                t = threading.Thread(target=self._refresh, args=(bucket,),
                                     daemon=True)
                with self._lock:
                    self._threads = [x for x in self._threads
                                     if x.is_alive()] + [t]
                t.start()
            else:
                self._refresh(bucket)
        with self._lock:
            return bucket, rec.table

    def drain(self, timeout_s: float = 30.0) -> None:
        """Join in-flight background refreshes (tests / clean shutdown)."""
        with self._lock:
            threads = list(self._threads)
        deadline = time.perf_counter() + timeout_s
        for t in threads:
            t.join(max(0.0, deadline - time.perf_counter()))
            if t.is_alive():
                raise RuntimeError("background plan refresh did not "
                                   f"finish within {timeout_s}s")

    # --- telemetry --------------------------------------------------------

    @property
    def verdict_flips(self) -> int:
        with self._lock:
            return sum(r.flips for r in self._buckets.values())

    def telemetry(self) -> dict:
        """Per-bucket hit/miss/build/flip counters + table digests, and
        the service-wide lookup hit rate — embedded in the serving
        engine's telemetry() `adaptive` block and the adaptive bench."""
        with self._lock:
            buckets = {}
            hits = misses = 0
            for (b, l), rec in sorted(self._buckets.items()):
                hits += rec.hits
                misses += rec.misses
                buckets[f"b{b}xl{l}"] = {
                    "batch_edge": b,
                    "len_edge": l,
                    "hits": rec.hits,
                    "misses": rec.misses,
                    "builds": rec.builds,
                    "flips": rec.flips,
                    "flipped_labels": list(rec.flipped_labels),
                    "refresh_in_flight": rec.refreshing,
                    "last_build_s": rec.last_build_s,
                    "table_digest": (rec.table.digest
                                     if rec.table is not None else None),
                }
            total = hits + misses
            return {
                "lattice": {"batch_edges": list(self.lattice.batch_edges),
                            "len_edges": list(self.lattice.len_edges)},
                "refresh_every": self.refresh_every,
                "backend": self.backend,
                "background": self.background,
                "lookups": total,
                "hit_rate": hits / total if total else None,
                "verdict_flips": sum(r.flips
                                     for r in self._buckets.values()),
                "buckets": buckets,
            }
