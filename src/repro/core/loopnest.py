"""Timeloop-lite loop-nest access counting (paper §III-B, Fig. 4).

A mapping is a nest of tiling loops.  For a tensor resident at some level,
the number of *fills* (fetches from the parent level) equals the resident
footprint times a *revisit factor* over the loops above that level:

  - loops over dimensions irrelevant to the tensor, encountered before any
    relevant loop (walking inner -> outer), reuse the resident tile: skipped;
  - from the first relevant loop outward, every loop iteration changes (or
    revisits) the tile, so every factor multiplies.

This is exactly the effect Fig. 4 illustrates: the dimension placed in the
outermost loop multiplies the access factors of the other tensors.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, Sequence

Loop = tuple[str, int]          # (dim name in {"M","N","K"}, trip count)

RELEVANT = {
    "A": frozenset({"M", "K"}),
    "W": frozenset({"K", "N"}),
    "Z": frozenset({"M", "N"}),
}

# Canonical dim order the greedy rule breaks trip-count ties with (it is
# the order candidate_mappings emits DRAM loops in, and Python's stable
# sort preserves it).  vectorized.evaluate_flat's in-kernel greedy
# selection mirrors exactly this (dim, index) tie-break, so the batched
# and scalar greedy paths pick the same permutation bit-for-bit.
CANONICAL_DIMS = ("M", "K", "N")

# The DRAM-order selection modes every layer supports.  Single source of
# truth: vectorized.evaluate_flat, sweep.SweepEngine and planner.decide
# all validate against this tuple, so no layer can drift into accepting
# (or silently rerouting) a mode another layer rejects.
ORDER_MODES = ("exact", "greedy")


def check_order_mode(order_mode: str) -> None:
    if order_mode not in ORDER_MODES:
        raise ValueError(f"unknown order_mode {order_mode!r}; "
                         f"expected one of {ORDER_MODES}")


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def revisit_factor(loops_above: Sequence[Loop], tensor: str) -> int:
    """Revisit multiplier for `tensor` given loops above its residency,
    ordered innermost first."""
    rel = RELEVANT[tensor]
    r = 1
    seen_relevant = False
    for dim, f in loops_above:
        if f <= 1:
            continue
        if dim in rel:
            seen_relevant = True
        if seen_relevant:
            r *= f
    return r


def fills(footprint: int, loops_above: Sequence[Loop], tensor: str) -> int:
    """Number of elements fetched from the parent level into a residency of
    `footprint` elements, given the loops above it (innermost first)."""
    return footprint * revisit_factor(loops_above, tensor)


def coverage_factor(loops_above: Sequence[Loop], tensor: str) -> int:
    """Number of *distinct* tiles the loops above iterate for `tensor`
    (product of relevant loop trips only).  revisit_factor / coverage_factor
    = how many times each distinct tile is re-visited (for the output
    tensor: partial-sum spill round-trips)."""
    rel = RELEVANT[tensor]
    c = 1
    for dim, f in loops_above:
        if dim in rel:
            c *= f
    return c


def best_order(loops: Sequence[Loop],
               score_fn,
               ) -> tuple[tuple[Loop, ...], float]:
    """Exact minimizer over all permutations of a (small) loop level.

    `score_fn(order)` -> cost.  Paper §IV-B uses a greedy rule (smallest
    loop factor outermost); `greedy_order` implements that; this exact
    search is the beyond-paper default (≤ 3! = 6 permutations).
    """
    best, best_cost = None, math.inf
    for perm in itertools.permutations(loops):
        c = score_fn(perm)
        if c < best_cost:
            best, best_cost = perm, c
    return tuple(best), best_cost


def greedy_order(loops: Sequence[Loop]) -> tuple[Loop, ...]:
    """Paper-faithful greedy rule: the dimension with the *smallest* loop
    factor goes outermost (minimizes the common multiplier of the other
    tensors' access factors — the Fig. 4 argument), descending inward.

    Returned order is innermost-first (consistent with `revisit_factor`):
    largest factor innermost ... smallest factor outermost.  Ties keep
    the input order (stable sort) — see CANONICAL_DIMS.
    """
    return tuple(sorted(loops, key=lambda lf: -lf[1]))


def greedy_perm(trips: dict) -> tuple[str, ...]:
    """The innermost-first dim permutation the greedy rule picks for the
    given {dim: trip-count} DRAM loops (dims considered in CANONICAL_DIMS
    order, as candidate_mappings emits them).

    This is the scalar reference for the per-row permutation
    vectorized.evaluate_flat selects in-kernel under order_mode="greedy".
    """
    loops = [(d, trips[d]) for d in CANONICAL_DIMS]
    return tuple(d for d, _ in greedy_order(loops))


@dataclasses.dataclass(frozen=True)
class TensorTraffic:
    """Per-tensor element counts crossing one level boundary."""
    reads: float = 0.0       # elements read from the parent (far) level
    writes: float = 0.0      # elements written back to the parent level

    def __add__(self, o: "TensorTraffic") -> "TensorTraffic":
        return TensorTraffic(self.reads + o.reads, self.writes + o.writes)


def output_rmw_traffic(tile_elems: int, loops_above: Sequence[Loop],
                       ) -> tuple[float, float]:
    """Partial-sum read/write element counts for the output tensor Z.

    Z is revisited `r` times; each residency ends with a write-back, and all
    but the first begin with a read of the previous partial sums.  Returns
    (psum_reads, psum_writes) in elements; the final write is included in
    psum_writes (caller may cost the last MN elements at output precision).
    """
    r = revisit_factor(loops_above, "Z")
    writes = tile_elems * r
    reads = tile_elems * max(0, r - 1)
    return float(reads), float(writes)
