"""Priority-based CiM mapping algorithm (paper §IV-B, Algorithm 1).

Priorities, in order:
  1. Weight stationarity: K -> CiM rows, N -> CiM columns; partial sums are
     reduced in-array along K.
  2. Utilization via parallelism: weights are spread across multiple
     primitives before filling the serial (Rh/Ch) extents of one unit;
     the K-vs-N expansion across primitives keeps the mapped-dimension
     ratio below a threshold (paper: 4).
  3. Input/weight reuse: the largest possible input block (M1 x K-tile) is
     held in the adjacent memory level (SMEM); then the N and K factors of
     that level are grown while capacity allows (Algorithm 1).
  4. Loop order: compute keeps M < K < N (M innermost); outer memory levels
     use the greedy smallest-factor-outermost rule or the exact
     6-permutation minimizer (see loopnest.best_order).
"""
from __future__ import annotations

import dataclasses

from .gemm import GEMM
from .loopnest import Loop, ceil_div, greedy_order
from .memory import LEVELS, SMEM, CiMSystemConfig

PSUM_BYTES = 4  # partial-sum precision (INT8 inputs -> 32-bit accumulators)


@dataclasses.dataclass(frozen=True)
class CiMMapping:
    """A complete mapping of one GEMM onto a CiM-integrated hierarchy.

    Spatial (within/across arrays):
      k_arr, n_arr : active rows / cols of the weight tile per array
      pk, pn       : primitives along K and N (pk*pn <= n_prims)
    Buffer residency (SMEM when CiM sits at RF; disabled for CiM@SMEM):
      m1           : M elements streamed per residency block
      fk, fn       : growth factors — the buffered input tile is
                     (m1 x k0*fk), the buffered output tile is (m1 x n0*fn)
    DRAM level:
      dram_loops   : remaining (dim, trips) loops, innermost first
    """

    gemm: GEMM
    cfg: CiMSystemConfig
    k_arr: int
    n_arr: int
    pk: int
    pn: int
    m1: int
    fk: int
    fn: int
    dram_loops: tuple[Loop, ...]

    # ---- derived geometry -------------------------------------------------
    @property
    def k0(self) -> int:
        """Spatial K extent across all arrays."""
        return self.k_arr * self.pk

    @property
    def n0(self) -> int:
        """Spatial N extent across all arrays."""
        return self.n_arr * self.pn

    @property
    def n_arrays(self) -> int:
        return self.pk * self.pn

    @property
    def k_tiles(self) -> int:
        return ceil_div(self.gemm.K, self.k0)

    @property
    def n_tiles(self) -> int:
        return ceil_div(self.gemm.N, self.n0)

    @property
    def m2(self) -> int:
        return ceil_div(self.gemm.M, self.m1)

    @property
    def k2(self) -> int:
        """DRAM-level K trips (above the buffered fk tiles)."""
        return ceil_div(self.k_tiles, self.fk)

    @property
    def n2(self) -> int:
        return ceil_div(self.n_tiles, self.fn)

    @property
    def waves(self) -> int:
        """Total array-activation groups: one per (m, K-tile, N-tile)."""
        return self.gemm.M * self.k_tiles * self.n_tiles

    @property
    def utilization(self) -> float:
        """Mapped weight positions / total MAC units (paper §V-D)."""
        p = self.cfg.prim
        mapped_k = min(self.gemm.K, self.k0)
        mapped_n = min(self.gemm.N, self.n0)
        total = self.cfg.resolved_n_prims() * p.mac_units
        return (mapped_k * mapped_n) / total

    def validate(self) -> None:
        p, g = self.cfg.prim, self.gemm
        assert 1 <= self.k_arr <= p.k_rows, self
        assert 1 <= self.n_arr <= p.n_cols, self
        assert self.pk * self.pn <= self.cfg.resolved_n_prims(), self
        assert self.k_arr * self.n_arr <= p.capacity_bytes, self
        assert self.m1 >= 1 and self.fk >= 1 and self.fn >= 1, self
        # the buffered tiles must fit the buffer level (Algorithm 1 check)
        if self.cfg.cim_level == "RF":
            a = self.m1 * min(g.K, self.k0 * self.fk)
            z = self.m1 * min(g.N, self.n0 * self.fn) * PSUM_BYTES
            assert a + z <= SMEM.capacity_bytes, (a, z, self)
        # full coverage
        assert self.k0 * self.fk * self.k2 >= g.K, self
        assert self.n0 * self.fn * self.n2 >= g.N, self
        assert self.m1 * self.m2 >= g.M, self


def _minfactor(rem: int) -> int | None:
    """Smallest prime factor of `rem` (> 1), None when fully mapped.

    Algorithm 1's Minfactor: the next loop-factor increment available for a
    dimension with `rem` un-mapped trips.
    """
    if rem <= 1:
        return None
    for p in (2, 3, 5, 7):
        if rem % p == 0:
            return p
    # fall back: rem itself (prime or awkward); Algorithm 1 would take it
    for p in range(11, int(rem ** 0.5) + 1, 2):
        if rem % p == 0:
            return p
    return rem


def dimension_optimize(capacity: int, m_used: int, k_elems: int,
                       n_elems: int, n_rem_tiles: int,
                       psum_bytes: int = PSUM_BYTES) -> int:
    """Algorithm 1 (Dimension Optimization for N).

    Grows the N loop factor at the buffer level while the input block
    (m_used x k_elems) plus output block (m_used x n_elems*factor) fit.
    `n_rem_tiles` is the number of N tiles still unmapped above this level.
    Returns the achieved factor.
    """
    a_size = m_used * k_elems
    factor = 1
    while a_size + m_used * n_elems * factor * psum_bytes <= capacity:
        nf = _minfactor(ceil_div(n_rem_tiles, factor))
        if nf is None:
            break  # N fully mapped
        if a_size + m_used * n_elems * factor * nf * psum_bytes > capacity:
            break
        factor *= nf
    return factor


def allocate_primitives(gemm: GEMM, cfg: CiMSystemConfig
                        ) -> tuple[int, int, int, int]:
    """Priority 2: spread weights across primitives, K->rows / N->cols,
    keeping the mapped K:N extent ratio within the balance threshold.

    Returns (k_arr, n_arr, pk, pn).
    """
    p = cfg.prim
    n_prims = cfg.resolved_n_prims()
    thr = cfg.kn_balance_threshold
    k_arr = min(gemm.K, p.k_rows)
    n_arr = min(gemm.N, p.n_cols)
    need_k = ceil_div(gemm.K, k_arr)      # arrays to fully cover K
    need_n = ceil_div(gemm.N, n_arr)
    best = (k_arr, n_arr, 1, 1)
    best_score = None
    for pk in range(1, n_prims + 1):
        if pk > need_k:
            break
        pn_max = n_prims // pk
        for pn in range(1, pn_max + 1):
            if pn > need_n:
                break
            k0, n0 = k_arr * pk, n_arr * pn
            # paper §IV-B: expansion across primitives must stay balanced —
            # the larger-to-smaller expansion ratio must be < threshold
            # (Fig. 6b skewed vs 6c balanced).
            ratio = max(pk, pn) / min(pk, pn)
            if ratio >= thr and pk * pn > 1:
                continue
            # priority: parallelism (arrays used), then coverage balance
            covered = min(gemm.K, k0) * min(gemm.N, n0)
            score = (pk * pn, covered, -ratio)
            if best_score is None or score > best_score:
                best_score = score
                best = (k_arr, n_arr, pk, pn)
    return best


def _buffer_candidates(gemm: GEMM, k0: int, n0: int, k_tiles: int,
                       n_tiles: int) -> list[tuple[int, int, int]]:
    """Candidate (m1, fk, fn) buffer residencies, per the paper's priorities.

    The paper's greedy goal is "reducing the number of data accesses"; which
    tensor to hold deep depends on the GEMM shape, so we emit the candidate
    residencies its priority rules produce and let the cost model pick:
      (a) input-stationary: the A block spans full K (the weight matrix
          streams once per M block — maximum input reuse, paper Fig. 6a),
      (b) k0-deep streaming: A streams per spatial K tile; the psum block
          grows along N via Algorithm 1 (A refetched once per N super-tile),
      (c) output-stationary: the psum block spans full N (best for tiny M,
          e.g. GEMV decode rows).
    """
    cap = int(SMEM.capacity_bytes)
    cands: list[tuple[int, int, int]] = []

    # (a) full-K input block
    a_depth = min(gemm.K, k0 * k_tiles)
    m1 = cap // (a_depth + n0 * PSUM_BYTES)
    if m1 >= 1:
        m1 = min(gemm.M, m1)
        fn = dimension_optimize(cap, m1, a_depth, n0, n_tiles)
        cands.append((m1, k_tiles, fn))

    # (b) k0-deep streaming + Algorithm 1 N growth
    m1 = min(gemm.M, max(1, cap // (k0 + n0 * PSUM_BYTES)))
    fn = dimension_optimize(cap, m1, k0, n0, n_tiles)
    cands.append((m1, 1, fn))

    # (c) full-N psum block
    z_width = min(gemm.N, n0 * n_tiles)
    m1 = cap // (k0 + z_width * PSUM_BYTES)
    if m1 >= 1:
        m1 = min(gemm.M, m1)
        # deepen the input block with what is left (Algorithm 1 on K)
        fk = 1
        while True:
            nf = _minfactor(ceil_div(k_tiles, fk))
            if nf is None:
                break
            if m1 * min(gemm.K, k0 * fk * nf) \
                    + m1 * z_width * PSUM_BYTES > cap:
                break
            fk *= nf
        cands.append((m1, fk, n_tiles))

    return sorted(set(cands))


def candidate_mappings(gemm: GEMM, cfg: CiMSystemConfig,
                       order_mode: str = "exact") -> list[CiMMapping]:
    """All residencies the priority algorithm considers; the cost model
    (cost_model.evaluate) picks the access-minimal one — the paper's stated
    greedy objective."""
    k_arr, n_arr, pk, pn = allocate_primitives(gemm, cfg)
    k0, n0 = k_arr * pk, n_arr * pn
    k_tiles = ceil_div(gemm.K, k0)
    n_tiles = ceil_div(gemm.N, n0)

    if cfg.cim_level == "RF":
        triples = _buffer_candidates(gemm, k0, n0, k_tiles, n_tiles)
    else:
        # CiM at SMEM: all capacity is CiM arrays; no buffer level.
        triples = [(gemm.M, 1, 1)]

    out = []
    for m1, fk, fn in triples:
        m2 = ceil_div(gemm.M, m1)
        k2 = ceil_div(k_tiles, fk)
        n2 = ceil_div(n_tiles, fn)
        loops: tuple[Loop, ...] = (("M", m2), ("K", k2), ("N", n2))
        if order_mode == "greedy":
            loops = greedy_order(loops)
        m = CiMMapping(gemm=gemm, cfg=cfg, k_arr=k_arr, n_arr=n_arr, pk=pk,
                       pn=pn, m1=m1, fk=fk, fn=fn, dram_loops=loops)
        m.validate()
        out.append(m)
    return out


def priority_map(gemm: GEMM, cfg: CiMSystemConfig,
                 order_mode: str = "exact") -> CiMMapping:
    """The paper's priority-based mapping algorithm, end to end (first
    candidate; prefer cost_model.evaluate which scores all candidates).

    order_mode: "exact" evaluates all DRAM-level loop permutations inside
    the cost model; "greedy" fixes the paper's smallest-factor-outermost
    order up front (the batched path re-derives the same order per row
    in-kernel from the m2/k2/n2 trips — see vectorized.evaluate_flat).
    """
    return candidate_mappings(gemm, cfg, order_mode)[0]
