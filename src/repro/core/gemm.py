"""GEMM shape abstraction (paper §III-A, Table I).

A GEMM(M, N, K) multiplies an input matrix A (M×K) with a weight matrix
W (K×N) producing output Z (M×N).  Matrix-vector multiplication is the
special case M == 1.  All paper evaluations use INT8 (1 byte/element).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class GEMM:
    """A single GEMM workload instance.

    Attributes:
      M: rows of the input/output matrix (paper: input rows, e.g. seq len
         or conv output pixels).
      N: columns of the weight/output matrix (e.g. output channels).
      K: reduction dimension.
      bits: data precision in bits (paper fixes 8; the widened What
         axis also evaluates 4).
      label: human-readable provenance ("BERT-Large QK^T", ...).
      count: how many times this exact GEMM occurs in the workload.
      fp: floating-point element format (FP8 when bits == 8); False is
         the paper's integer precision.
    """

    M: int
    N: int
    K: int
    bits: int = 8
    label: str = ""
    count: int = 1
    fp: bool = False

    def __post_init__(self) -> None:
        if min(self.M, self.N, self.K) < 1:
            raise ValueError(f"GEMM dims must be >= 1, got {self}")
        if self.fp and self.bits != 8:
            raise ValueError(f"fp GEMMs must be 8-bit (FP8), got {self}")

    @property
    def precision(self) -> str:
        """Canonical precision token: "int8" / "int4" / "fp8"."""
        return "fp8" if self.fp else f"int{self.bits}"

    # --- basic quantities -------------------------------------------------
    @property
    def macs(self) -> int:
        return self.M * self.N * self.K

    @property
    def ops(self) -> int:
        """Operations = 2·MACs (multiply + accumulate), paper Fig. 2."""
        return 2 * self.macs

    @property
    def bytes_per_elem(self) -> float:
        return self.bits / 8.0

    @property
    def input_elems(self) -> int:
        return self.M * self.K

    @property
    def weight_elems(self) -> int:
        return self.K * self.N

    @property
    def output_elems(self) -> int:
        return self.M * self.N

    @property
    def total_elems(self) -> int:
        return self.input_elems + self.weight_elems + self.output_elems

    @property
    def algorithmic_reuse(self) -> float:
        """Paper eq. (1): 2·MNK / (BP·(MN + NK + MK)), ops per byte."""
        return self.ops / (self.bytes_per_elem * self.total_elems)

    def scaled(self, **kw) -> "GEMM":
        return dataclasses.replace(self, **kw)

    def __str__(self) -> str:  # pragma: no cover - debugging nicety
        tag = f" [{self.label}]" if self.label else ""
        return f"GEMM(M={self.M}, N={self.N}, K={self.K}){tag}"


# --- Table I constructors -----------------------------------------------


def conv2d_gemm(h_o: int, w_o: int, c_o: int, h_k: int, w_k: int, c_i: int,
                label: str = "", count: int = 1) -> GEMM:
    """Convolution lowered by im2col (Table I row 1).

    M = H_o·W_o, N = C_o, K = H_k·W_k·C_i  (kernel spatial × input channels).
    """
    return GEMM(M=h_o * w_o, N=c_o, K=h_k * w_k * c_i, label=label, count=count)


def fc_gemm(out_dim: int, in_dim: int, batch: int = 1, label: str = "",
            count: int = 1) -> GEMM:
    """Fully connected layer (Table I row 2): M=out, N=batch, K=in.

    Note the paper's convention places batch on N so that the weight matrix
    (K×N) is ... historically the paper writes (M=output dim, N=batch,
    K=input dim); with batch=1 this is a GEMV in M.  We keep the convention
    used by Table VI instead (DLRM rows are M=1, N=out, K=in), i.e. weights
    stationary as K×N:
    """
    return GEMM(M=batch, N=out_dim, K=in_dim, label=label, count=count)


def attention_gemms(seq: int, d_model: int, n_q_heads: int | None = None,
                    n_kv_heads: int | None = None, d_head: int | None = None,
                    label: str = "", count: int = 1) -> list[GEMM]:
    """The attention-layer GEMMs of Table I for one layer (single batch).

    Q/K/V projections, fused scores QKᵀ, QKᵀV, and output projection.
    When GQA head counts are given, K/V projections shrink accordingly.
    """
    if d_head is None:
        d_head = d_model // (n_q_heads or 1) if n_q_heads else d_model
    q_out = (n_q_heads or 1) * d_head if n_q_heads else d_model
    kv_out = (n_kv_heads or n_q_heads or 1) * d_head if n_kv_heads else d_model
    lab = (label + " " if label else "")
    gemms = [
        GEMM(M=seq, N=q_out, K=d_model, label=lab + "Wq", count=count),
        GEMM(M=seq, N=kv_out, K=d_model, label=lab + "Wk", count=count),
        GEMM(M=seq, N=kv_out, K=d_model, label=lab + "Wv", count=count),
        # per-head scores; expressed as fused (paper: single-batch fused)
        GEMM(M=seq, N=seq, K=d_head, label=lab + "QK^T",
             count=count * (n_q_heads or 1)),
        GEMM(M=seq, N=d_head, K=seq, label=lab + "QK^T.V",
             count=count * (n_q_heads or 1)),
        GEMM(M=seq, N=d_model, K=q_out, label=lab + "Wo", count=count),
    ]
    return gemms


def total_ops(gemms: Iterable[GEMM]) -> int:
    return sum(g.ops * g.count for g in gemms)


def geomean(xs: Iterable[float]) -> float:
    xs = [x for x in xs if x > 0]
    if not xs:
        return 0.0
    return math.exp(sum(math.log(x) for x in xs) / len(xs))
