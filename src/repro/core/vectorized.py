"""JAX-vectorized CiM + baseline cost model (beyond-paper contribution).

The analytical model in cost_model.py / baseline.py evaluates one
(GEMM, mapping) at a time in Python.  This module re-expresses the
closed-form traffic/energy/latency equations as jnp ops over *batched*
tensors, so a TPU/GPU (or XLA-CPU) evaluates tens of thousands of
candidate mappings in one fused kernel — turning the paper's Table-II
runtime comparison on its head: the heuristic search space can simply be
enumerated, and whole workloads (every GEMM x every CiM system config x
every candidate mapping) are scored under a single `jax.jit` call (see
repro.core.sweep, which drives the planner through this path).

Three entry points:
  * `evaluate_flat(batch)` — the fused kernel.  Every row of `batch` is a
    complete (GEMM dims, system config, mapping) tuple, so one call can
    mix GEMMs, CiM@RF and CiM@SMEM configs, and primitives freely.  The
    DRAM loop order is scored for all 6 permutations in-kernel; under
    order_mode="exact" the min-energy order is taken (cost_model's
    "exact" mode), under order_mode="greedy" each row keeps its
    smallest-factor-outermost order, selected in-kernel (see
    `_greedy_mask`) so the greedy planner path needs no scalar fallback.
  * `evaluate_batch(gemm, cfg, mappings)` — legacy convenience wrapper:
    B mappings of one GEMM on one config (broadcasts dims/config).
  * `evaluate_baseline_flat(batch)` — the tensor-core baseline counterpart
    (paper §V-A): scores (tile, super-tile) rows over all 36 RF x DRAM
    loop-permutation pairs in-kernel, lexicographic (time, energy) min —
    exactly baseline.evaluate_baseline's search objective.

Validated against the scalar models in tests/test_vectorized.py and the
planner-verdict parity suite in tests/test_sweep.py.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from .baseline import SPATIAL_M, SPATIAL_N, tile_candidates
from .cost_model import DRAM_STREAM_EFFICIENCY
from .gemm import GEMM
from .loopnest import CANONICAL_DIMS, RELEVANT, check_order_mode
from .mapping import PSUM_BYTES
from .memory import DRAM, RF, SMEM, TEMPORAL_REDUCTION_PJ, CiMSystemConfig
from .primitives import TENSOR_CORE, TensorCoreSpec

_ORDERS = list(itertools.permutations(["M", "K", "N"]))

# Row layout of an evaluate_flat batch: GEMM dims + precision + mapping +
# system config.
GEMM_FIELDS = ("M", "N", "K")
PREC_FIELDS = ("bits", "is_fp")
MAP_FIELDS = ("k_arr", "n_arr", "pk", "pn", "m1", "fk", "fn")
CFG_FIELDS = ("n_prims", "at_rf", "serialize", "k_rows", "n_cols",
              "Rp", "Cp", "mac_units", "latency_ns", "mac_energy_pj",
              "prim_capacity", "is_analog")
FLAT_FIELDS = GEMM_FIELDS + PREC_FIELDS + MAP_FIELDS + CFG_FIELDS

# Baseline batch layout: GEMM dims + RF tile + SMEM super-tile factors.
BASE_TILE_FIELDS = ("mt", "nt", "kt", "ms", "ns", "ks")
BASE_FLAT_FIELDS = GEMM_FIELDS + BASE_TILE_FIELDS


def config_row(cfg: CiMSystemConfig) -> dict:
    """The CFG_FIELDS scalars describing one CiM system config."""
    p = cfg.prim
    return {
        "n_prims": cfg.resolved_n_prims(),
        "at_rf": int(cfg.cim_level == "RF"),
        "serialize": int(cfg.serialize_primitives),
        "k_rows": p.k_rows, "n_cols": p.n_cols,
        "Rp": p.Rp, "Cp": p.Cp, "mac_units": p.mac_units,
        "latency_ns": p.latency_ns, "mac_energy_pj": p.mac_energy_pj,
        "prim_capacity": p.capacity_bytes,
        "is_analog": int(p.compute_type == "analog"),
    }


def precision_row(gemm: GEMM) -> dict:
    """The PREC_FIELDS scalars describing one GEMM's element format."""
    return {"bits": gemm.bits, "is_fp": int(gemm.fp)}


def _accesses(n_bytes, level):
    """Whole accesses for a byte stream at a memory level — the batched
    equivalent of MemoryLevel.energy_pj's ceil (charging fractional
    accesses under-counts by up to 8x on byte-scale degenerate GEMMs)."""
    return jnp.ceil(n_bytes / level.access_granularity_bytes)


def _revisit_seq(pairs, tensor: str):
    """Vectorized loopnest.revisit_factor over an explicit innermost-first
    sequence of (dim, trips-array) pairs.

    Matches the scalar rule exactly: loops with trip count <= 1 are
    skipped entirely (they neither multiply nor mark the tensor as
    'seen'), irrelevant loops inside the first relevant one multiply.
    """
    rel = RELEVANT[tensor]
    some = pairs[0][1]
    r = jnp.ones_like(some)
    seen = jnp.zeros_like(some, dtype=bool)
    for dim, t in pairs:
        active = t > 1
        is_rel = dim in rel                    # static python bool
        mult = jnp.where((seen | is_rel) & active, t, 1.0)
        r = r * mult
        if is_rel:
            seen = seen | active
    return r


def _revisit_vec(trips: dict, order: tuple, tensor: str):
    """Reuse rule for one static loop order (trips: dim -> (B,) array)."""
    return _revisit_seq([(dim, trips[dim]) for dim in order], tensor)


def _coverage_vec(trips: dict, tensor: str):
    """Vectorized loopnest.coverage_factor (permutation-independent)."""
    rel = RELEVANT[tensor]
    c = jnp.ones_like(trips["M"])
    for dim in ("M", "K", "N"):
        if dim in rel:
            c = c * trips[dim]
    return c


# Tie-break index of each dim in the greedy rule (loopnest.CANONICAL_DIMS:
# Python's stable sort keeps the candidate_mappings emission order M, K, N
# on equal trip counts).
_GREEDY_IDX = {d: i for i, d in enumerate(CANONICAL_DIMS)}


def _greedy_mask(trips: dict, order: tuple):
    """(B,) bool: rows whose greedy DRAM order is exactly `order`.

    loopnest.greedy_order is a stable descending sort on trip counts
    (largest innermost), i.e. the total order key(d) = (-trips[d],
    canonical index).  A permutation (d0, d1, d2), innermost-first, is the
    greedy one iff key(d0) < key(d1) < key(d2) — exactly one of the 6
    static permutations matches per row, so selecting each order's cost
    under its mask reproduces the scalar greedy path bit-for-bit.
    """
    def precedes(a, b):
        ta, tb = trips[a], trips[b]
        if _GREEDY_IDX[a] < _GREEDY_IDX[b]:   # static: tie keeps a first
            return ta >= tb
        return ta > tb

    d0, d1, d2 = order
    return precedes(d0, d1) & precedes(d1, d2)


# --- backend-shared CiM cost spec -------------------------------------------
# ONE description of the cost model, consumed by BOTH sweep backends: the
# XLA path (`evaluate_flat` below) and the fused Pallas kernel
# (repro.kernels.sweep_eval) call exactly these functions on their own
# array layouts — (B,) columns under XLA, (1, block) row slices of the
# stacked field matrix inside the Pallas kernel.  Any change to the cost
# equations lands in both backends at once, which is what lets the
# differential-testing harness (tests/test_sweep_properties.py) pin the
# backends to each other instead of to two hand-maintained copies.


def cim_cast(batch: dict) -> dict:
    """FLAT_FIELDS columns cast to the dtypes the cost equations use
    (float32 throughout, bool for the two config flags)."""
    f32 = jnp.float32
    cols = {f: batch[f].astype(f32) for f in FLAT_FIELDS}
    cols["at_rf"] = batch["at_rf"].astype(bool)
    cols["serialize"] = batch["serialize"].astype(bool)
    cols["is_fp"] = batch["is_fp"].astype(bool)
    cols["is_analog"] = batch["is_analog"].astype(bool)
    return cols


def cim_precision_factors(cols: dict):
    """Batched counterpart of primitives.precision_factors: (energy_x,
    latency_x, colpar_x) per row from the bits / is_fp / is_analog
    columns.  Exactly (1, 1, 1) at INT8, so the Table-IV calibration
    point is bitwise untouched on 8-bit integer rows."""
    bits = cols["bits"]
    is_fp, is_analog = cols["is_fp"], cols["is_analog"]
    r = bits / 8.0
    pow2 = jnp.exp2(bits - 8.0)
    energy_int = jnp.where(is_analog, 0.4 * r + 0.6 * pow2, r * r)
    latency_int = jnp.where(is_analog, 0.5 + 0.5 * r, r)
    colpar_int = jnp.where(is_analog, 8.0 / bits, 1.0)
    energy_x = jnp.where(is_fp, jnp.where(is_analog, 1.3, 1.2), energy_int)
    latency_x = jnp.where(is_fp, jnp.where(is_analog, 1.5, 1.25), latency_int)
    colpar_x = jnp.where(is_fp, jnp.where(is_analog, 0.5, 1.0), colpar_int)
    return energy_x, latency_x, colpar_x


def cim_row_terms(cols: dict) -> dict:
    """Order-independent terms of the CiM cost model: validity, compute
    time, level-local traffic/energy, and the DRAM trip counts feeding
    the per-order costs (`cim_order_cost`) and selection
    (`cim_best_order`)."""
    M, N, K = cols["M"], cols["N"], cols["K"]
    k_arr, n_arr = cols["k_arr"], cols["n_arr"]
    pk, pn, m1 = cols["pk"], cols["pn"], cols["m1"]
    fk, fn = cols["fk"], cols["fn"]
    n_prims, at_rf = cols["n_prims"], cols["at_rf"]
    serialize = cols["serialize"]
    k_rows, n_cols = cols["k_rows"], cols["n_cols"]
    Rp, Cp = cols["Rp"], cols["Cp"]
    mac_units = cols["mac_units"]
    latency_ns = cols["latency_ns"]
    mac_energy_pj = cols["mac_energy_pj"]
    prim_capacity = cols["prim_capacity"]

    k0 = jnp.minimum(k_arr * pk, K)
    n0 = jnp.minimum(n_arr * pn, N)
    k_tiles = jnp.ceil(K / k0)
    n_tiles = jnp.ceil(N / n0)
    m2 = jnp.ceil(M / m1)
    k2 = jnp.ceil(k_tiles / fk)
    n2 = jnp.ceil(n_tiles / fn)
    waves = M * k_tiles * n_tiles
    macs = M * N * K
    ops = 2.0 * macs
    input_elems = M * K
    weight_elems = K * N
    output_elems = M * N

    # --- validity (same checks as CiMMapping.validate) ---
    a_block = m1 * jnp.minimum(K, k0 * fk)
    z_block = m1 * jnp.minimum(N, n0 * fn) * PSUM_BYTES
    fits_buffer = a_block + z_block <= SMEM.capacity_bytes
    valid = ((k_arr >= 1) & (k_arr <= k_rows)
             & (n_arr >= 1) & (n_arr <= n_cols)
             & (pk * pn <= n_prims)
             & (k_arr * n_arr <= prim_capacity)
             & (m1 >= 1) & (fk >= 1) & (fn >= 1)
             & (~at_rf | fits_buffer))   # buffer check only applies at RF

    # --- compute time (primitives share the input driver only at RF) ---
    # per-precision macro scaling (identity at INT8): latency_x stretches
    # each activation step, colpar_x rescales the usable column
    # parallelism, energy_x scales the per-MAC energy below
    energy_x, latency_x, colpar_x = cim_precision_factors(cols)
    row_steps = jnp.ceil(k_arr / Rp)
    col_steps = jnp.ceil(n_arr / (Cp * colpar_x))
    serial = jnp.where(serialize & at_rf, pk * pn, 1.0)
    compute_ns = (waves * row_steps * col_steps * serial
                  * latency_ns * latency_x)

    # --- level-local traffic + compute energy ---
    # energy is charged in whole accesses per tensor stream, exactly like
    # the scalar reference (MemoryLevel.energy_pj ceils) — fractional
    # per-byte charging diverges 8x at degenerate byte-scale GEMMs, which
    # is how the property harness caught the old formulation
    a_smem_reads = jnp.where(at_rf, waves * k0, 0.0)
    z_smem_rmw = jnp.where(at_rf, 2.0 * waves * n0 * PSUM_BYTES, 0.0)
    smem_bytes = a_smem_reads + z_smem_rmw
    e_smem = (_accesses(a_smem_reads, SMEM) + _accesses(z_smem_rmw, SMEM)
              ) * SMEM.access_energy_pj
    e_mac = macs * mac_energy_pj * energy_x
    adds = output_elems * jnp.maximum(0.0, k_tiles * row_steps - 1)
    e_red = adds * TEMPORAL_REDUCTION_PJ

    # CiM@SMEM: inputs stream straight from DRAM, psums spill per K-tile
    # (order-independent — no buffer level between DRAM and the arrays).
    a_smem_lvl = waves * k0
    z_smem_lvl = (output_elems
                  + 2.0 * output_elems * jnp.maximum(0.0, k_tiles - 1)
                  * PSUM_BYTES)
    # weights are written into the arrays through the hosting level's port
    host_gran = jnp.where(at_rf, float(RF.access_granularity_bytes),
                          float(SMEM.access_granularity_bytes))
    host_energy = jnp.where(at_rf, RF.access_energy_pj,
                            SMEM.access_energy_pj)

    trips = {"M": m2, "K": k2, "N": n2}
    util = (jnp.minimum(K, k0) * jnp.minimum(N, n0)
            / (n_prims * mac_units))
    return {
        "valid": valid, "compute_ns": compute_ns,
        "smem_bytes": smem_bytes, "e_smem": e_smem, "e_mac": e_mac,
        "e_red": e_red, "trips": trips, "at_rf": at_rf,
        "w_foot": jnp.minimum(K, k0 * fk) * jnp.minimum(N, n0 * fn),
        "z_tile": m1 * jnp.minimum(N, n0 * fn),
        "cz": _coverage_vec(trips, "Z"),
        "a_block": a_block, "a_smem_lvl": a_smem_lvl,
        "z_smem_lvl": z_smem_lvl, "host_gran": host_gran,
        "host_energy": host_energy,
        "input_elems": input_elems, "weight_elems": weight_elems,
        "output_elems": output_elems, "ops": ops, "utilization": util,
    }


def cim_order_cost(pre: dict, order: tuple):
    """(energy_pj, dram_bytes) of one static DRAM loop order, given the
    order-independent terms from `cim_row_terms`."""
    trips = pre["trips"]
    w_fills = jnp.maximum(pre["w_foot"] * _revisit_vec(trips, order, "W"),
                          pre["weight_elems"])
    a_rf_fills = jnp.maximum(
        pre["a_block"] * _revisit_vec(trips, order, "A"),
        pre["input_elems"])
    rz = _revisit_vec(trips, order, "Z")
    spills = pre["z_tile"] * jnp.maximum(0.0, rz - pre["cz"])
    z_rf_bytes = jnp.maximum(
        pre["z_tile"] * pre["cz"] + 2.0 * spills * PSUM_BYTES,
        pre["output_elems"])
    a_fills = jnp.where(pre["at_rf"], a_rf_fills, pre["a_smem_lvl"])
    z_bytes = jnp.where(pre["at_rf"], z_rf_bytes, pre["z_smem_lvl"])
    dram_bytes = w_fills + a_fills + z_bytes
    # whole accesses per tensor stream (W/A/Z ceil separately), matching
    # the scalar reference's per-tensor MemoryLevel.energy_pj calls
    e_dram = (_accesses(w_fills, DRAM) + _accesses(a_fills, DRAM)
              + _accesses(z_bytes, DRAM)) * DRAM.access_energy_pj
    e_w_write = (jnp.ceil(w_fills / pre["host_gran"])
                 * pre["host_energy"])
    energy = (e_dram + e_w_write + pre["e_smem"] + pre["e_mac"]
              + pre["e_red"])
    return energy, dram_bytes


def cim_best_order(pre: dict, order_mode: str):
    """In-kernel DRAM-order selection over the 6 statically unrolled
    permutations: "exact" keeps the min-energy order, "greedy" keeps each
    row's smallest-factor-outermost order via the `_greedy_mask` one-hot
    (exactly one order matches per row, tie-breaks matching
    loopnest.greedy_order bit-for-bit)."""
    some = pre["trips"]["M"]
    best_energy = jnp.full_like(some, jnp.inf)
    best_dram = jnp.zeros_like(some)
    for order in _ORDERS:
        energy, dram_bytes = cim_order_cost(pre, order)
        if order_mode == "greedy":
            keep = _greedy_mask(pre["trips"], order)
        else:
            keep = energy < best_energy
        best_energy = jnp.where(keep, energy, best_energy)
        best_dram = jnp.where(keep, dram_bytes, best_dram)
    return best_energy, best_dram


def cim_outputs(pre: dict, best_energy, best_dram,
                dram_eff: float = DRAM_STREAM_EFFICIENCY) -> dict:
    """Assemble the public output dict from the selected order's cost."""
    valid = pre["valid"]
    ops = pre["ops"]
    dram_ns = best_dram / (DRAM.bandwidth_bytes_per_cycle * dram_eff)
    smem_ns = pre["smem_bytes"] / SMEM.bandwidth_bytes_per_cycle
    time_ns = jnp.maximum(pre["compute_ns"],
                          jnp.maximum(dram_ns, smem_ns))
    inf = jnp.float32(jnp.inf)
    return {
        "valid": valid,
        "energy_pj": jnp.where(valid, best_energy, inf),
        "time_ns": jnp.where(valid, time_ns, inf),
        "tops_per_w": jnp.where(valid, ops / best_energy, 0.0),
        "gflops": jnp.where(valid, ops / time_ns, 0.0),
        "utilization": jnp.where(valid, pre["utilization"], 0.0),
        "compute_ns": pre["compute_ns"],
        "dram_ns": dram_ns,
        "smem_ns": smem_ns,
        "dram_bytes": best_dram,
        "smem_bytes": pre["smem_bytes"],
    }


def evaluate_flat(batch: dict, dram_eff: float = DRAM_STREAM_EFFICIENCY,
                  order_mode: str = "exact"):
    """Evaluate B flattened (GEMM, config, mapping) rows at once.

    batch: dict of (B,) arrays for every name in FLAT_FIELDS.  Rows may
    mix different GEMMs, primitives, and CiM levels (RF vs SMEM — the two
    traffic models are computed branch-free and selected per row).

    order_mode (static under jit): "exact" keeps the min-energy DRAM loop
    order of all 6 permutations (cost_model's exact mode); "greedy" keeps
    each row's smallest-factor-outermost order, selected in-kernel
    (`cim_best_order`), so order_mode="greedy" needs no scalar fallback.

    This is the XLA-fused backend; the Pallas backend
    (repro.kernels.sweep_eval) runs the same shared spec functions inside
    one hand-written kernel.

    Returns dict of (B,) arrays: valid (bool), energy_pj, time_ns,
    tops_per_w, gflops, utilization, compute_ns, dram_ns, smem_ns,
    dram_bytes, smem_bytes.  Invalid rows get inf energy/time and zero
    rate metrics.
    """
    check_order_mode(order_mode)
    pre = cim_row_terms(cim_cast(batch))
    best_energy, best_dram = cim_best_order(pre, order_mode)
    return cim_outputs(pre, best_energy, best_dram, dram_eff)


def evaluate_batch(gemm: GEMM, cfg: CiMSystemConfig, mappings: dict,
                   dram_eff: float = DRAM_STREAM_EFFICIENCY):
    """Evaluate B candidate mappings of one GEMM on one config at once.

    mappings: dict of (B,) int32 arrays for MAP_FIELDS.  Broadcast wrapper
    around `evaluate_flat` (which additionally batches GEMM dims and the
    system config — use it directly for whole-workload sweeps).
    """
    b = mappings["k_arr"].shape[0]
    batch = {f: jnp.asarray(mappings[f]) for f in MAP_FIELDS}
    consts = {"M": gemm.M, "N": gemm.N, "K": gemm.K,
              **precision_row(gemm), **config_row(cfg)}
    for name, v in consts.items():
        batch[name] = jnp.full((b,), float(v), jnp.float32)
    return evaluate_flat(batch, dram_eff)


# --- tensor-core baseline ---------------------------------------------------


def evaluate_baseline_flat(batch: dict,
                           spec: TensorCoreSpec = TENSOR_CORE):
    """Score B flattened (GEMM, tile, super-tile) baseline rows at once.

    batch: dict of (B,) arrays for BASE_FLAT_FIELDS (GEMM dims + the
    mt/nt/kt RF tile and ms/ns/ks SMEM growth factors that
    baseline.tile_candidates enumerates).  All 36 (RF x DRAM) loop-order
    permutation pairs are scored in-kernel and the lexicographic
    (time_ns, energy_pj) min is kept — the same objective
    baseline.evaluate_baseline minimizes.  Rows violating the RF/SMEM
    capacity checks get inf time/energy.
    """
    f32 = jnp.float32
    M = batch["M"].astype(f32)
    N = batch["N"].astype(f32)
    K = batch["K"].astype(f32)
    mt = batch["mt"].astype(f32)
    nt = batch["nt"].astype(f32)
    kt = batch["kt"].astype(f32)
    ms = batch["ms"].astype(f32)
    ns = batch["ns"].astype(f32)
    ks = batch["ks"].astype(f32)

    mtc = jnp.minimum(M, mt)
    ntc = jnp.minimum(N, nt)
    ktc = jnp.minimum(K, kt)
    sm_m = jnp.minimum(M, mt * ms)
    sm_n = jnp.minimum(N, nt * ns)
    sm_k = jnp.minimum(K, kt * ks)
    macs = M * N * K
    ops = 2.0 * macs
    out_elems = M * N

    # --- validity (BaselineMapping.validate) ---
    rf_bytes = mt * kt + kt * nt + mt * nt * PSUM_BYTES
    smem_foot = sm_m * sm_k + sm_k * sm_n + sm_m * sm_n * PSUM_BYTES
    valid = ((rf_bytes <= RF.capacity_bytes)
             & (smem_foot <= SMEM.capacity_bytes))

    # --- order-independent energy terms ---
    k_rf_trips = jnp.ceil(K / ktc)
    rf_reads = 2.0 * macs
    z_rf_rmw = 2.0 * out_elems * k_rf_trips * PSUM_BYTES
    # one ceil over the level total, as baseline.py's RF.energy_pj call
    e_rf = _accesses(rf_reads + z_rf_rmw, RF) * RF.access_energy_pj
    e_pe = 2.0 * macs * spec.pe_buffer_energy_pj
    e_mac = macs * spec.mac_energy_pj
    adds = out_elems * jnp.maximum(0.0, k_rf_trips - 1.0)
    e_red = adds * TEMPORAL_REDUCTION_PJ

    eff_m = mtc / (jnp.ceil(mtc / SPATIAL_M) * SPATIAL_M)
    eff_n = ntc / (jnp.ceil(ntc / SPATIAL_N) * SPATIAL_N)
    util = eff_m * eff_n
    compute_ns = (macs / (spec.macs_per_cycle * jnp.maximum(util, 1e-9))
                  / spec.freq_ghz)

    rf_trips = {"M": ms, "K": ks, "N": ns}
    dram_trips = {"M": jnp.ceil(M / (mt * ms)),
                  "K": jnp.ceil(K / (kt * ks)),
                  "N": jnp.ceil(N / (nt * ns))}
    # coverage factors are permutation-independent: hoist out of the loop
    cz_smem = _coverage_vec(dram_trips, "Z")
    czr_rf = cz_smem * _coverage_vec(rf_trips, "Z")

    best = None
    for rf_perm in _ORDERS:
        rf_pairs = [(d, rf_trips[d]) for d in rf_perm]
        for dram_perm in _ORDERS:
            dram_pairs = [(d, dram_trips[d]) for d in dram_perm]
            above_rf = rf_pairs + dram_pairs

            a_fills = jnp.maximum(
                sm_m * sm_k * _revisit_seq(dram_pairs, "A"), M * K)
            w_fills = jnp.maximum(
                sm_k * sm_n * _revisit_seq(dram_pairs, "W"), K * N)
            rz = _revisit_seq(dram_pairs, "Z")
            z_spill = sm_m * sm_n * jnp.maximum(0.0, rz - cz_smem)
            z_dram = sm_m * sm_n * cz_smem + 2.0 * z_spill * PSUM_BYTES
            dram_bytes = a_fills + w_fills + jnp.maximum(z_dram, out_elems)
            e_dram = _accesses(dram_bytes, DRAM) * DRAM.access_energy_pj

            a_rf = jnp.maximum(mtc * ktc * _revisit_seq(above_rf, "A"),
                               M * K)
            w_rf = jnp.maximum(ktc * ntc * _revisit_seq(above_rf, "W"),
                               K * N)
            rzr = _revisit_seq(above_rf, "Z")
            z_rf = (mtc * ntc * czr_rf
                    + 2.0 * mtc * ntc * jnp.maximum(0.0, rzr - czr_rf)
                    * PSUM_BYTES)
            smem_bytes = a_rf + w_rf + z_rf
            e_smem = _accesses(smem_bytes, SMEM) * SMEM.access_energy_pj

            energy = e_dram + e_smem + e_rf + e_pe + e_mac + e_red
            dram_ns = dram_bytes / DRAM.bandwidth_bytes_per_cycle
            smem_ns = smem_bytes / SMEM.bandwidth_bytes_per_cycle
            time_ns = jnp.maximum(compute_ns,
                                  jnp.maximum(dram_ns, smem_ns))
            cand = {"time_ns": time_ns, "energy_pj": energy,
                    "dram_bytes": dram_bytes, "smem_bytes": smem_bytes,
                    "dram_ns": dram_ns, "smem_ns": smem_ns}
            if best is None:
                best = cand
            else:
                better = ((time_ns < best["time_ns"])
                          | ((time_ns == best["time_ns"])
                             & (energy < best["energy_pj"])))
                best = {k: jnp.where(better, cand[k], best[k])
                        for k in cand}

    inf = jnp.float32(jnp.inf)
    return {
        "valid": valid,
        "energy_pj": jnp.where(valid, best["energy_pj"], inf),
        "time_ns": jnp.where(valid, best["time_ns"], inf),
        "tops_per_w": jnp.where(valid, ops / best["energy_pj"], 0.0),
        "gflops": jnp.where(valid, ops / best["time_ns"], 0.0),
        "utilization": jnp.where(valid, util, 0.0),
        "compute_ns": compute_ns,
        "dram_ns": best["dram_ns"],
        "smem_ns": best["smem_ns"],
        "dram_bytes": best["dram_bytes"],
        "smem_bytes": best["smem_bytes"],
    }


def enumerate_baseline_space(gemm: GEMM) -> dict:
    """The tile grid baseline.evaluate_baseline searches, as host (numpy)
    batch arrays — same enumeration order, so tie-breaks resolve
    identically.  Kept on host so whole-workload sweeps concatenate many
    grids into one device transfer (repro.core.sweep)."""
    grid = list(tile_candidates(gemm))
    arr = np.asarray(grid, np.float32)
    out = {n: arr[:, i] for i, n in enumerate(BASE_TILE_FIELDS)}
    b = arr.shape[0]
    for name, v in (("M", gemm.M), ("N", gemm.N), ("K", gemm.K)):
        out[name] = np.full((b,), float(v), np.float32)
    return out


# --- exhaustive mapping-space search ---------------------------------------


def enumerate_space(gemm: GEMM, cfg: CiMSystemConfig,
                    max_points: int = 200_000) -> dict:
    """Full power-of-two mapping space as batched arrays."""
    p = cfg.prim
    n_prims = cfg.resolved_n_prims()

    def pow2s(limit):
        out, v = [], 1
        while v <= limit:
            out.append(v)
            v *= 2
        return out

    ks = pow2s(min(gemm.K, p.k_rows))
    ns = pow2s(min(gemm.N, p.n_cols))
    ps = list(range(1, n_prims + 1))
    ms = pow2s(gemm.M)
    fs = pow2s(4096)
    grid = list(itertools.product(ks, ns, ps, ps, ms, fs, fs))
    if len(grid) > max_points:
        rng = np.random.default_rng(0)
        idx = rng.choice(len(grid), max_points, replace=False)
        grid = [grid[i] for i in idx]
    arr = np.asarray(grid, np.int32)
    return {n: jnp.asarray(arr[:, i]) for i, n in enumerate(MAP_FIELDS)}


def exhaustive_best(gemm: GEMM, cfg: CiMSystemConfig,
                    objective: str = "energy_pj"):
    """Enumerate + evaluate the whole space on-device; returns the best
    metrics dict (scalars) and the winning mapping parameters."""
    space = enumerate_space(gemm, cfg)
    out = jax.jit(lambda s: evaluate_batch(gemm, cfg, s))(space)
    i = int(jnp.argmin(out[objective]))
    best = {k: float(v[i]) for k, v in out.items()}
    best_map = {k: int(v[i]) for k, v in space.items()}
    return best, best_map, int(space["m1"].shape[0])
