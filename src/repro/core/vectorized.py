"""JAX-vectorized CiM cost model (beyond-paper contribution).

The analytical model in cost_model.py evaluates one (GEMM, mapping) at a
time in Python.  This module re-expresses the closed-form traffic/energy/
latency equations as jnp ops over *batched* mapping tensors, so a TPU/GPU
(or XLA-CPU) evaluates tens of thousands of candidate mappings in one
fused kernel — turning the paper's Table-II runtime comparison on its
head: the heuristic search space can simply be enumerated.

Scope: CiM@RF with the (m1, fk, fn) buffer residency and the fixed
M<K<N compute order; the DRAM loop order is scored for all 6 permutations
in-kernel and the min is taken (exactly cost_model's "exact" mode).
Validated against the scalar model in tests/test_vectorized.py.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from .gemm import GEMM
from .loopnest import RELEVANT
from .mapping import PSUM_BYTES
from .memory import DRAM, RF, SMEM, TEMPORAL_REDUCTION_PJ, CiMSystemConfig
from .cost_model import DRAM_STREAM_EFFICIENCY

_ORDERS = list(itertools.permutations(["M", "K", "N"]))


def _revisit_vec(trips: dict, order: tuple, tensor: str):
    """Vectorized reuse rule for one loop order (trips: dim -> (B,) int)."""
    rel = RELEVANT[tensor]
    r = jnp.ones_like(trips["M"])
    seen = jnp.zeros_like(trips["M"], dtype=bool)
    for dim in order:                      # innermost first
        t = trips[dim]
        is_rel = dim in rel
        seen_now = seen | (is_rel & (jnp.ones_like(seen)))
        mult = jnp.where(seen | is_rel, t, 1)
        r = r * jnp.where(mult > 0, mult, 1)
        seen = seen_now
    return r


def _coverage_vec(trips: dict, tensor: str):
    rel = RELEVANT[tensor]
    c = jnp.ones_like(trips["M"])
    for dim in ("M", "K", "N"):
        if dim in rel:
            c = c * trips[dim]
    return c


def evaluate_batch(gemm: GEMM, cfg: CiMSystemConfig, mappings: dict,
                   dram_eff: float = DRAM_STREAM_EFFICIENCY):
    """Evaluate B candidate mappings of one GEMM at once.

    mappings: dict of (B,) int32 arrays: k_arr, n_arr, pk, pn, m1, fk, fn.
    Returns dict of (B,) arrays: energy_pj, time_ns, tops_per_w, gflops,
    utilization, valid (bool).
    """
    p = cfg.prim
    g = gemm
    f32 = jnp.float32
    k_arr = mappings["k_arr"].astype(f32)
    n_arr = mappings["n_arr"].astype(f32)
    pk = mappings["pk"].astype(f32)
    pn = mappings["pn"].astype(f32)
    m1 = mappings["m1"].astype(f32)
    fk = mappings["fk"].astype(f32)
    fn = mappings["fn"].astype(f32)

    k0 = jnp.minimum(k_arr * pk, g.K)
    n0 = jnp.minimum(n_arr * pn, g.N)
    k_tiles = jnp.ceil(g.K / k0)
    n_tiles = jnp.ceil(g.N / n0)
    m2 = jnp.ceil(g.M / m1)
    k2 = jnp.ceil(k_tiles / fk)
    n2 = jnp.ceil(n_tiles / fn)
    waves = g.M * k_tiles * n_tiles

    # --- validity (same checks as CiMMapping.validate) ---
    n_prims = cfg.resolved_n_prims()
    a_block = m1 * jnp.minimum(g.K, k0 * fk)
    z_block = m1 * jnp.minimum(g.N, n0 * fn) * PSUM_BYTES
    valid = ((k_arr >= 1) & (k_arr <= p.k_rows)
             & (n_arr >= 1) & (n_arr <= p.n_cols)
             & (pk * pn <= n_prims)
             & (k_arr * n_arr <= p.capacity_bytes)
             & (a_block + z_block <= SMEM.capacity_bytes)
             & (m1 >= 1) & (fk >= 1) & (fn >= 1))

    # --- compute time ---
    row_steps = jnp.ceil(k_arr / p.Rp)
    col_steps = jnp.ceil(n_arr / p.Cp)
    serial = pk * pn if cfg.serialize_primitives else jnp.ones_like(pk)
    compute_ns = waves * row_steps * col_steps * serial * p.latency_ns

    # --- traffic over the 6 DRAM orders; take min energy ---
    trips = {"M": m2, "K": k2, "N": n2}
    best_energy = jnp.full_like(m1, jnp.inf)
    best_dram = jnp.zeros_like(m1)
    smem_bytes = (waves * k0
                  + 2.0 * waves * n0 * PSUM_BYTES)
    e_smem = (smem_bytes / SMEM.access_granularity_bytes
              * SMEM.access_energy_pj)
    e_mac = g.macs * p.mac_energy_pj
    adds = g.output_elems * jnp.maximum(0.0, k_tiles * row_steps - 1)
    e_red = adds * TEMPORAL_REDUCTION_PJ

    for order in _ORDERS:
        w_fills = jnp.maximum(
            jnp.minimum(g.K, k0 * fk) * jnp.minimum(g.N, n0 * fn)
            * _revisit_vec(trips, order, "W"), g.weight_elems)
        a_fills = jnp.maximum(
            a_block * _revisit_vec(trips, order, "A"), g.input_elems)
        rz = _revisit_vec(trips, order, "Z")
        cz = _coverage_vec(trips, "Z")
        z_tile = m1 * jnp.minimum(g.N, n0 * fn)
        spills = z_tile * jnp.maximum(0.0, rz - cz)
        z_bytes = jnp.maximum(z_tile * cz + 2 * spills * PSUM_BYTES,
                              float(g.output_elems))
        dram_bytes = w_fills + a_fills + z_bytes
        e_dram = (dram_bytes / DRAM.access_granularity_bytes
                  * DRAM.access_energy_pj)
        e_w_write = (w_fills / RF.access_granularity_bytes
                     * RF.access_energy_pj)
        energy = e_dram + e_w_write + e_smem + e_mac + e_red
        better = energy < best_energy
        best_energy = jnp.where(better, energy, best_energy)
        best_dram = jnp.where(better, dram_bytes, best_dram)

    dram_ns = best_dram / (DRAM.bandwidth_bytes_per_cycle * dram_eff)
    smem_ns = smem_bytes / SMEM.bandwidth_bytes_per_cycle
    time_ns = jnp.maximum(compute_ns, jnp.maximum(dram_ns, smem_ns))

    util = (jnp.minimum(g.K, k0) * jnp.minimum(g.N, n0)
            / (n_prims * p.mac_units))
    inf = jnp.float32(jnp.inf)
    ops = jnp.float32(float(g.ops))    # g.ops can exceed int32 (e.g. 4096³)
    return {
        "valid": valid,
        "energy_pj": jnp.where(valid, best_energy, inf),
        "time_ns": jnp.where(valid, time_ns, inf),
        "tops_per_w": jnp.where(valid, ops / best_energy, 0.0),
        "gflops": jnp.where(valid, ops / time_ns, 0.0),
        "utilization": jnp.where(valid, util, 0.0),
    }


def enumerate_space(gemm: GEMM, cfg: CiMSystemConfig,
                    max_points: int = 200_000) -> dict:
    """Full power-of-two mapping space as batched arrays."""
    p = cfg.prim
    n_prims = cfg.resolved_n_prims()

    def pow2s(limit):
        out, v = [], 1
        while v <= limit:
            out.append(v)
            v *= 2
        return out

    ks = pow2s(min(gemm.K, p.k_rows))
    ns = pow2s(min(gemm.N, p.n_cols))
    ps = list(range(1, n_prims + 1))
    ms = pow2s(gemm.M)
    fs = pow2s(4096)
    grid = list(itertools.product(ks, ns, ps, ps, ms, fs, fs))
    if len(grid) > max_points:
        rng = np.random.default_rng(0)
        idx = rng.choice(len(grid), max_points, replace=False)
        grid = [grid[i] for i in idx]
    arr = np.asarray(grid, np.int32)
    names = ("k_arr", "n_arr", "pk", "pn", "m1", "fk", "fn")
    return {n: jnp.asarray(arr[:, i]) for i, n in enumerate(names)}


def exhaustive_best(gemm: GEMM, cfg: CiMSystemConfig,
                    objective: str = "energy_pj"):
    """Enumerate + evaluate the whole space on-device; returns the best
    metrics dict (scalars) and the winning mapping parameters."""
    space = enumerate_space(gemm, cfg)
    out = jax.jit(lambda s: evaluate_batch(gemm, cfg, s))(space)
    i = int(jnp.argmin(out[objective]))
    best = {k: float(v[i]) for k, v in out.items()}
    best_map = {k: int(v[i]) for k, v in space.items()}
    return best, best_map, int(space["m1"].shape[0])
