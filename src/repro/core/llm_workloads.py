"""GEMM extraction from the assigned LM architectures (DESIGN.md §2).

Lowers each (arch x shape) cell into the paper's Table-I GEMM taxonomy so
the WWW planner can answer what/when/where for modern LM workloads:
train/prefill => large-M GEMMs; decode => the paper's M=1 pathology
(batched: M = batch).
"""
from __future__ import annotations

from ..configs.base import ModelConfig, ShapeConfig
from .gemm import GEMM


def gemms_of_model(cfg: ModelConfig, shape: ShapeConfig) -> list[GEMM]:
    """Per-step GEMM list with per-layer counts.

    Decode uses M = global_batch (one token per sequence); train/prefill
    use M = seq_len with count x batch (the paper's single-batch
    convention, scaled by occurrence count).
    """
    s, b = shape.seq_len, shape.global_batch
    decode = shape.kind == "decode"
    M = b if decode else s
    per_seq = 1 if decode else b
    d, dh = cfg.d_model, cfg.head_dim()
    out: list[GEMM] = []

    n_attn = cfg.n_layers
    n_mamba = 0
    if cfg.family == "ssm":
        n_attn, n_mamba = 0, cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
        n_mamba = cfg.n_layers - n_attn
    elif cfg.family == "vlm" and cfg.vision:
        # cross-attn layers run the xattn-* projections counted below,
        # not the self-attn ones — don't double-count them here
        n_attn = cfg.n_layers - cfg.n_layers // cfg.vision.cross_attn_every

    def add(m, n, k, label, count):
        if count > 0 and min(m, n, k) >= 1:
            out.append(GEMM(int(m), int(n), int(k), label=label,
                            count=int(count)))

    # --- attention projections ---
    if n_attn:
        add(M, cfg.n_heads * dh, d, f"{cfg.name} Wq", n_attn * per_seq)
        add(M, cfg.n_kv_heads * dh, d, f"{cfg.name} Wk", n_attn * per_seq)
        add(M, cfg.n_kv_heads * dh, d, f"{cfg.name} Wv", n_attn * per_seq)
        add(M, d, cfg.n_heads * dh, f"{cfg.name} Wo", n_attn * per_seq)
        # score GEMMs (per head); decode: 1 x cache x dh
        kv_len = min(s, cfg.sliding_window) if cfg.sliding_window else s
        if decode:
            add(b, kv_len, dh, f"{cfg.name} qK^T (decode)",
                n_attn * cfg.n_heads)
            add(b, dh, kv_len, f"{cfg.name} pV (decode)",
                n_attn * cfg.n_heads)
        else:
            add(s, kv_len, dh, f"{cfg.name} QK^T",
                n_attn * cfg.n_heads * per_seq)
            add(s, dh, kv_len, f"{cfg.name} QK^T.V",
                n_attn * cfg.n_heads * per_seq)

    # --- FFN / experts ---
    if cfg.moe:
        moe_layers = cfg.n_layers // cfg.moe.every_n_layers
        dense_layers = (cfg.n_layers - moe_layers
                        if cfg.family == "hybrid" else 0)
        tokens = M
        per_expert_m = max(1, tokens * cfg.moe.top_k // cfg.moe.n_experts)
        for nm, wn, wk in (("gate", cfg.moe.expert_d_ff, d),
                           ("up", cfg.moe.expert_d_ff, d),
                           ("down", d, cfg.moe.expert_d_ff)):
            add(per_expert_m, wn, wk, f"{cfg.name} expert-{nm}",
                moe_layers * cfg.moe.n_experts * per_seq)
        if cfg.moe.n_shared_experts:
            for nm, wn, wk in (("gate", cfg.moe.shared_d_ff, d),
                               ("up", cfg.moe.shared_d_ff, d),
                               ("down", d, cfg.moe.shared_d_ff)):
                add(M, wn, wk, f"{cfg.name} shared-{nm}",
                    moe_layers * per_seq)
        for nm, wn, wk in (("gate", cfg.d_ff, d), ("up", cfg.d_ff, d),
                           ("down", d, cfg.d_ff)):
            if dense_layers and cfg.d_ff:
                add(M, wn, wk, f"{cfg.name} mlp-{nm}",
                    dense_layers * per_seq)
    elif cfg.d_ff and cfg.family != "ssm":
        # pure-SSM periods are (mamba, None): no FFN slot exists even if
        # the config carries a (smoke-default) d_ff
        for nm, wn, wk in (("gate", cfg.d_ff, d), ("up", cfg.d_ff, d),
                           ("down", d, cfg.d_ff)):
            add(M, wn, wk, f"{cfg.name} mlp-{nm}",
                cfg.n_layers * per_seq)

    # --- mamba mixer projections ---
    if n_mamba and cfg.ssm:
        di = cfg.ssm.d_inner(d)
        nh = cfg.ssm.n_ssm_heads(d)
        gdim = cfg.ssm.n_groups * cfg.ssm.d_state
        add(M, di, d, f"{cfg.name} ssm-z", n_mamba * per_seq)
        add(M, di, d, f"{cfg.name} ssm-x", n_mamba * per_seq)
        add(M, 2 * gdim + nh, d, f"{cfg.name} ssm-BCdt",
            n_mamba * per_seq)
        add(M, d, di, f"{cfg.name} ssm-out", n_mamba * per_seq)

    # --- vision cross-attn K/V from image tokens ---
    if cfg.family == "vlm" and cfg.vision:
        n_cross = cfg.n_layers // cfg.vision.cross_attn_every
        nimg = cfg.vision.n_image_tokens
        add(nimg, cfg.n_kv_heads * dh, d, f"{cfg.name} xattn-KV",
            2 * n_cross * per_seq)
        add(M, cfg.n_heads * dh, d, f"{cfg.name} xattn-Q",
            n_cross * per_seq)
        add(M, d, cfg.n_heads * dh, f"{cfg.name} xattn-out",
            n_cross * per_seq)
        if not decode:
            add(s, nimg, dh, f"{cfg.name} xattn-scores",
                2 * n_cross * cfg.n_heads * per_seq)

    # --- LM head ---
    add(M, cfg.vocab, d, f"{cfg.name} lm_head", per_seq)
    return out


def phase_gemms_of_model(cfg: ModelConfig, seq_len: int,
                         batch: int) -> dict[str, list[GEMM]]:
    """The serving phases of one model as separate GEMM sets.

    {"prefill": gemms at M = seq_len (kind="prefill"),
     "decode":  gemms at M = batch  (kind="decode")}

    This is the input `planner.plan_workload_by_phase` expects: the same
    architecture produces structurally different What/When verdicts per
    phase (prefill's large-M reuse vs decode's M=batch GEMV pathology),
    and the serving stack gates each phase by its own plan table."""
    from ..configs.base import ShapeConfig
    return {
        "prefill": gemms_of_model(
            cfg, ShapeConfig("phase-prefill", seq_len, batch, "prefill")),
        "decode": gemms_of_model(
            cfg, ShapeConfig("phase-decode", seq_len, batch, "decode")),
    }


# GEMMs whose labels match these markers multiply two *activations*
# (attention scores / probability-weighted values): there is no stationary
# weight to quantize, so the runtime projection gate never sees them.
ACTIVATION_GEMM_MARKERS = ("qK^T", "pV (decode)", "QK^T", "xattn-scores")


def is_projection_label(label: str) -> bool:
    """True for GEMMs with a stationary weight operand (the labels the
    model-side `linear(...)` execution layer consumes)."""
    return not any(m in label for m in ACTIVATION_GEMM_MARKERS)


def projection_labels(cfg: ModelConfig, shape: ShapeConfig) -> set[str]:
    """Short (model-prefix-stripped) labels of all weight projections of
    one (arch x shape) cell — the exact label set the model stack must
    route through `models.layers.linear` (coverage-tested)."""
    prefix = f"{cfg.name} "
    return {g.label[len(prefix):] if g.label.startswith(prefix) else g.label
            for g in gemms_of_model(cfg, shape)
            if is_projection_label(g.label)}
