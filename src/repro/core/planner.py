"""What/When/Where planner — the paper's three questions as a decision layer.

For every GEMM of a workload it evaluates:
  * the tensor-core baseline,
  * each CiM primitive at RF (iso-area count),
  * each CiM primitive at SMEM configA (RF count) and configB (16x),
and reports the winner per objective.  In the LM framework this gates
kernel selection: GEMMs whose best option is CiM-like (weight-stationary,
large M, K within reduction reach) run the weight-stationary INT8 Pallas
path; memory-bound M=1 decode GEMMs stay on the standard path (the paper's
"when NOT to CiM" takeaway).

Backends (`decide` / `plan_workload` accept
backend="vectorized"|"pallas"|"scalar"):
  * "vectorized" (default): the batched sweep engine (repro.core.sweep) —
    all GEMMs x configs x candidate mappings scored in one fused jax.jit
    call through vectorized.evaluate_flat, with an LRU result cache keyed
    by (backend, GEMM, config, order_mode).  Both order modes ("exact"
    and "greedy") run fully batched — the greedy smallest-factor-
    outermost DRAM order is selected per row in-kernel, so there is no
    scalar fallback on any path.
  * "pallas": the same batched sweep, but the CiM rows run through the
    fused hand-written Pallas kernel (repro.kernels.sweep_eval) instead
    of relying on XLA fusion.  Identical verdicts by construction (both
    kernels consume vectorized.py's backend-shared cost spec); platforms
    without Pallas lowering fall back to the XLA kernel with the reason
    recorded in sweep cache telemetry.
  * "scalar": the original per-call Python cost model — kept as the
    reference for parity testing (tests/test_sweep.py and the
    property-based differential suite in tests/test_sweep_properties.py).
All backends apply the identical eligibility and "when" rules
(`make_decision`), so verdicts can only differ by float tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .baseline import evaluate_baseline
from .cost_model import Metrics, evaluate
from .gemm import GEMM
from .loopnest import check_order_mode
from .memory import CiMSystemConfig, configb_count
from .primitives import (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T,
                         CiMPrimitive)

DEFAULT_PRIMS = (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T)


PLANNER_BACKENDS = ("vectorized", "pallas", "scalar")


def _check_args(backend: str, order_mode: str) -> None:
    """Shared argument validation: every backend accepts exactly the same
    (backend, order_mode) combinations — no mode silently reroutes."""
    if backend not in PLANNER_BACKENDS:
        raise ValueError(f"unknown planner backend {backend!r}; "
                         f"expected one of {PLANNER_BACKENDS}")
    check_order_mode(order_mode)


def standard_configs(prims: Sequence[CiMPrimitive] = DEFAULT_PRIMS
                     ) -> dict[str, CiMSystemConfig]:
    """The paper's evaluated integration points."""
    cfgs: dict[str, CiMSystemConfig] = {}
    for p in prims:
        cfgs[f"{p.name}@RF"] = CiMSystemConfig(prim=p, cim_level="RF")
        cfgs[f"{p.name}@SMEM-A"] = CiMSystemConfig(
            prim=p, cim_level="SMEM",
            n_prims=CiMSystemConfig(prim=p, cim_level="RF").resolved_n_prims())
        cfgs[f"{p.name}@SMEM-B"] = CiMSystemConfig(
            prim=p, cim_level="SMEM", n_prims=configb_count(p))
    return cfgs


@dataclasses.dataclass(frozen=True)
class Decision:
    """Per-GEMM what/when/where verdict."""
    gemm: GEMM
    baseline: Metrics
    options: dict            # config name -> Metrics
    best_energy: str         # config name (or "baseline")
    best_throughput: str
    use_cim: bool            # paper's "when": does any CiM option beat the
                             # baseline in energy without losing throughput
                             # by more than 2x?

    @property
    def what(self) -> str:
        return self.best_energy

    @property
    def where(self) -> str:
        name = self.best_energy
        return name.split("@")[-1] if "@" in name else "PE"

    @property
    def chosen(self) -> Metrics:
        """Metrics of the deployable (eligible min-energy) option."""
        if self.best_energy == "baseline":
            return self.baseline
        return self.options[self.best_energy]


def make_decision(gemm: GEMM, base: Metrics, options: dict,
                  throughput_floor: float = 0.5) -> Decision:
    """Apply the what/when rules to already-evaluated options.

    Shared by the scalar path below and the batched sweep engine
    (repro.core.sweep), so the two backends cannot drift.  The deployable
    choice ("what") is the most energy-efficient option among those
    keeping >= `throughput_floor` of the baseline's throughput (a CiM
    deployment that collapses performance is not a win — paper §VI-A's
    latency/parallelism trade-off)."""
    all_opts = dict(options)
    all_opts["baseline"] = base
    eligible = {n: m for n, m in all_opts.items()
                if m.gflops >= throughput_floor * base.gflops}
    best_e = max(eligible, key=lambda n: eligible[n].tops_per_w)
    best_t = max(all_opts, key=lambda n: all_opts[n].gflops)
    # "when": only deploy CiM for a *meaningful* energy win (paper Tab. V:
    # low-reuse GEMVs show ~0 gain and lose throughput — not worth it)
    use_cim = (best_e != "baseline"
               and eligible[best_e].tops_per_w > 1.15 * base.tops_per_w)
    return Decision(gemm=gemm, baseline=base, options=options,
                    best_energy=best_e, best_throughput=best_t,
                    use_cim=use_cim)


def decide(gemm: GEMM, configs: dict[str, CiMSystemConfig] | None = None,
           order_mode: str = "exact",
           throughput_floor: float = 0.5,
           backend: str = "vectorized") -> Decision:
    """What/when/where for one GEMM.

    backend="vectorized" routes through the batched sweep engine (cached,
    one fused device call, both order modes in-kernel); backend="pallas"
    is the same sweep with the fused Pallas row kernel;
    backend="scalar" is the Python reference."""
    _check_args(backend, order_mode)
    configs = configs or standard_configs()
    if backend != "scalar":
        from .sweep import decide_batched
        return decide_batched(gemm, configs, order_mode, throughput_floor,
                              backend=backend)
    base = evaluate_baseline(gemm)
    options = {name: evaluate(gemm, cfg, order_mode)
               for name, cfg in configs.items()}
    return make_decision(gemm, base, options, throughput_floor)


def plan_workload(gemms: Iterable[GEMM],
                  configs: dict[str, CiMSystemConfig] | None = None,
                  order_mode: str = "exact",
                  backend: str = "vectorized") -> list[Decision]:
    """Per-GEMM decisions for a whole workload.

    The default vectorized backend flattens the entire workload into one
    batched evaluation (plus one for the baselines) instead of looping
    decide() — 10x+ faster on full llm_workloads sweeps (see
    benchmarks/sweep_bench.py) — in either order mode; backend="pallas"
    runs the same sweep through the fused Pallas row kernel."""
    _check_args(backend, order_mode)
    if backend != "scalar":
        from .sweep import plan_workload_batched
        return plan_workload_batched(gemms, configs, order_mode,
                                     backend=backend)
    return [decide(g, configs, order_mode, backend=backend)
            for g in gemms]


def plan_workload_by_phase(phase_gemms: dict,
                           configs: dict[str, CiMSystemConfig] | None = None,
                           order_mode: str = "exact",
                           backend: str = "vectorized"
                           ) -> dict[str, list[Decision]]:
    """Per-phase what/when/where plans: {"prefill": [...], "decode": [...]}.

    The paper's When answer is phase-dependent — prefill GEMMs carry
    M = seq_len reuse while decode GEMMs collapse to M = batch — so a
    single plan over a mixed workload mis-gates one phase or the other.
    Each phase is planned independently over its own GEMM set (one
    batched sweep per phase, shared result cache across phases for
    shapes that coincide).

    Raises ValueError on a phase with zero GEMMs: an empty phase plan
    would silently gate *nothing* for that phase (every lookup would
    KeyError at trace time at best, or — with a permissive table — run
    ungated), which is indistinguishable from a deliberate all-baseline
    verdict.  Callers that legitimately have no GEMMs for a phase must
    omit the phase, not pass an empty list."""
    _check_args(backend, order_mode)
    if not phase_gemms:
        raise ValueError("plan_workload_by_phase() needs at least one phase")
    plans: dict[str, list[Decision]] = {}
    for phase, gemms in phase_gemms.items():
        gemms = list(gemms)
        if not gemms:
            raise ValueError(
                f"phase {phase!r} has zero eligible GEMMs — an empty "
                "phase plan would silently disable gating for that phase; "
                "omit the phase instead of passing an empty workload")
        plans[phase] = plan_workload(gemms, configs, order_mode,
                                     backend=backend)
    return plans


def summarize(decisions: Sequence[Decision]) -> dict:
    """Aggregate what/when/where statistics over a workload.

    energy_gain_x compares the baseline against each GEMM's *deployable*
    option — d.options[d.best_energy], the eligible winner decide() would
    actually pick — not the unconstrained min-energy option, which could
    be a config the throughput floor rules out.

    Raises ValueError on an empty decision list: an all-zero aggregate
    is indistinguishable from a real workload where CiM never wins, and
    campaign certification legitimately produces empty contract-filtered
    subsets that must be reported as such, not as zeros."""
    if not decisions:
        raise ValueError(
            "summarize() needs at least one Decision — an empty list "
            "would silently aggregate to all zeros (campaign "
            "certification filters can produce empty subsets; report "
            "them explicitly instead)")
    n = len(decisions)
    cim_frac = sum(d.use_cim for d in decisions) / max(1, n)
    wheres: dict[str, int] = {}
    whats: dict[str, int] = {}
    for d in decisions:
        wheres[d.where] = wheres.get(d.where, 0) + 1
        whats[d.what] = whats.get(d.what, 0) + 1
    # energy-weighted gain vs baseline, over the eligible winners
    e_base = sum(d.baseline.energy_pj * d.gemm.count for d in decisions)
    e_best = sum(d.chosen.energy_pj * d.gemm.count for d in decisions)
    return {"n_gemms": n, "cim_fraction": cim_frac, "where": wheres,
            "what": whats,
            "energy_gain_x": e_base / e_best if e_best else 0.0}
