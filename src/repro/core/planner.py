"""What/When/Where planner — the paper's three questions as a decision layer.

For every GEMM of a workload it evaluates:
  * the tensor-core baseline,
  * each CiM primitive at RF (iso-area count),
  * each CiM primitive at SMEM configA (RF count) and configB (16x),
and reports the winner per objective.  In the LM framework this gates
kernel selection: GEMMs whose best option is CiM-like (weight-stationary,
large M, K within reduction reach) run the weight-stationary INT8 Pallas
path; memory-bound M=1 decode GEMMs stay on the standard path (the paper's
"when NOT to CiM" takeaway).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .baseline import evaluate_baseline
from .cost_model import Metrics, evaluate
from .gemm import GEMM
from .memory import CiMSystemConfig, configb_count
from .primitives import (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T,
                         CiMPrimitive)

DEFAULT_PRIMS = (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T)


def standard_configs(prims: Sequence[CiMPrimitive] = DEFAULT_PRIMS
                     ) -> dict[str, CiMSystemConfig]:
    """The paper's evaluated integration points."""
    cfgs: dict[str, CiMSystemConfig] = {}
    for p in prims:
        cfgs[f"{p.name}@RF"] = CiMSystemConfig(prim=p, cim_level="RF")
        cfgs[f"{p.name}@SMEM-A"] = CiMSystemConfig(
            prim=p, cim_level="SMEM",
            n_prims=CiMSystemConfig(prim=p, cim_level="RF").resolved_n_prims())
        cfgs[f"{p.name}@SMEM-B"] = CiMSystemConfig(
            prim=p, cim_level="SMEM", n_prims=configb_count(p))
    return cfgs


@dataclasses.dataclass(frozen=True)
class Decision:
    """Per-GEMM what/when/where verdict."""
    gemm: GEMM
    baseline: Metrics
    options: dict            # config name -> Metrics
    best_energy: str         # config name (or "baseline")
    best_throughput: str
    use_cim: bool            # paper's "when": does any CiM option beat the
                             # baseline in energy without losing throughput
                             # by more than 2x?

    @property
    def what(self) -> str:
        return self.best_energy

    @property
    def where(self) -> str:
        name = self.best_energy
        return name.split("@")[-1] if "@" in name else "PE"


def decide(gemm: GEMM, configs: dict[str, CiMSystemConfig] | None = None,
           order_mode: str = "exact",
           throughput_floor: float = 0.5) -> Decision:
    """What/when/where for one GEMM.

    The deployable choice ("what") is the most energy-efficient option
    among those keeping >= `throughput_floor` of the baseline's
    throughput (a CiM deployment that collapses performance is not a
    win — paper §VI-A's latency/parallelism trade-off)."""
    configs = configs or standard_configs()
    base = evaluate_baseline(gemm)
    options = {name: evaluate(gemm, cfg, order_mode)
               for name, cfg in configs.items()}
    all_opts = dict(options)
    all_opts["baseline"] = base
    eligible = {n: m for n, m in all_opts.items()
                if m.gflops >= throughput_floor * base.gflops}
    best_e = max(eligible, key=lambda n: eligible[n].tops_per_w)
    best_t = max(all_opts, key=lambda n: all_opts[n].gflops)
    # "when": only deploy CiM for a *meaningful* energy win (paper Tab. V:
    # low-reuse GEMVs show ~0 gain and lose throughput — not worth it)
    use_cim = (best_e != "baseline"
               and eligible[best_e].tops_per_w > 1.15 * base.tops_per_w)
    return Decision(gemm=gemm, baseline=base, options=options,
                    best_energy=best_e, best_throughput=best_t,
                    use_cim=use_cim)


def plan_workload(gemms: Iterable[GEMM],
                  configs: dict[str, CiMSystemConfig] | None = None,
                  order_mode: str = "exact") -> list[Decision]:
    return [decide(g, configs, order_mode) for g in gemms]


def summarize(decisions: Sequence[Decision]) -> dict:
    """Aggregate what/when/where statistics over a workload."""
    n = len(decisions)
    cim_frac = sum(d.use_cim for d in decisions) / max(1, n)
    wheres: dict[str, int] = {}
    whats: dict[str, int] = {}
    for d in decisions:
        wheres[d.where] = wheres.get(d.where, 0) + 1
        whats[d.what] = whats.get(d.what, 0) + 1
    # energy-weighted speedups vs baseline
    e_base = sum(d.baseline.energy_pj * d.gemm.count for d in decisions)
    e_best = sum(min(d.baseline.energy_pj,
                     min(m.energy_pj for m in d.options.values()))
                 * d.gemm.count for d in decisions)
    return {"n_gemms": n, "cim_fraction": cim_frac, "where": wheres,
            "what": whats,
            "energy_gain_x": e_base / e_best if e_best else 0.0}
