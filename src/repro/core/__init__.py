"""Core reproduction of "WWW: What, When, Where to Compute-in-Memory".

Public surface:
  GEMM, CiMPrimitive + the four Table-IV prototypes, CiMSystemConfig,
  priority_map (the paper's mapping algorithm), evaluate / evaluate_baseline
  (the analytical cost model), random_search (heuristic mapper baseline),
  decide / plan_workload (the what/when/where planner).
"""
from .baseline import evaluate_baseline
from .campaign import (CampaignResult, CampaignSpec, Constraint,
                       build_config, certify_front, certify_point,
                       parse_precision, run_campaign)
from .cost_model import Metrics, evaluate, evaluate_cim
from .gemm import GEMM, attention_gemms, conv2d_gemm, fc_gemm
from .heuristic import random_search
from .mapping import CiMMapping, priority_map
from .pareto import (ParetoAccumulator, dominates, pareto_mask,
                     pareto_mask_np)
from .memory import (DRAM, LEVELS, RF, SMEM, CiMSystemConfig, configb_count,
                     iso_area_primitive_count)
from .plan_service import BucketLattice, PlanService
from .planner import (Decision, decide, make_decision, plan_workload,
                      plan_workload_by_phase, standard_configs, summarize)
from .sweep import (SweepEngine, decide_batched, plan_workload_batched,
                    sweep_evaluate, sweep_evaluate_baseline)
from .primitives import (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T,
                         PRIMITIVES, SUPPORTED_BITS, TENSOR_CORE,
                         CiMPrimitive, TensorCoreSpec,
                         mac_energy_pj_from_tops_w, precision_factors,
                         tech_scale_ratio)
from .vectorized import evaluate_batch, exhaustive_best
from .workloads import (BERT_LARGE, DLRM, GPT_J, REAL_WORKLOADS, RESNET50,
                        square_sweep, synthetic_dataset)

__all__ = [
    "GEMM", "CiMPrimitive", "CiMSystemConfig", "CiMMapping", "Metrics",
    "priority_map", "evaluate", "evaluate_cim", "evaluate_baseline",
    "random_search", "decide", "plan_workload", "standard_configs",
    "summarize", "Decision", "plan_workload_by_phase",
    "ANALOG_6T", "ANALOG_8T", "DIGITAL_6T", "DIGITAL_8T", "PRIMITIVES",
    "TENSOR_CORE", "TensorCoreSpec", "DRAM", "SMEM", "RF", "LEVELS",
    "iso_area_primitive_count", "configb_count", "SUPPORTED_BITS",
    "mac_energy_pj_from_tops_w", "precision_factors", "tech_scale_ratio",
    "attention_gemms", "conv2d_gemm", "fc_gemm",
    "BERT_LARGE", "GPT_J", "DLRM", "RESNET50", "REAL_WORKLOADS",
    "synthetic_dataset", "square_sweep",
    "evaluate_batch", "exhaustive_best", "make_decision",
    "SweepEngine", "decide_batched", "plan_workload_batched",
    "sweep_evaluate", "sweep_evaluate_baseline",
    "BucketLattice", "PlanService",
    "CampaignSpec", "CampaignResult", "Constraint", "build_config",
    "run_campaign", "certify_point", "certify_front", "parse_precision",
    "ParetoAccumulator", "dominates", "pareto_mask", "pareto_mask_np",
]
