"""Batched What/When/Where sweep engine (the planner's fast path).

`planner.decide` answers the paper's three questions one scalar cost-model
call at a time: every GEMM x 12 system configs x ~3 candidate mappings x 6
loop orders, plus a ~1300-point tensor-core baseline search, all in
Python.  This module flattens the whole workload — every GEMM, every
config, every candidate mapping — into two device batches (CiM rows and
baseline tile rows) and scores each under ONE `jax.jit` call through
`vectorized.evaluate_flat` / `evaluate_baseline_flat`.  CiMLoop-style
batched analytical evaluation is what makes full design-space sweeps
tractable; here it makes full-workload planning 10x+ faster than the
scalar path (benchmarks/sweep_bench.py tracks the ratio).

Results are memoized in an LRU cache keyed by (GEMM shape, system config,
order_mode), so repeated decode-shape queries — the serving engine asks
about the same handful of GEMMs for every session — are answered without
touching the device at all.  `cache_info()` exposes hit/miss telemetry.

Only order_mode="exact" is supported (the batched kernels score all 6
DRAM orders and keep the min — exactly the scalar "exact" mode);
`planner.decide(backend="vectorized")` transparently falls back to the
scalar path for "greedy".

Verdict parity with the scalar path is enforced by tests/test_sweep.py.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Iterable, Sequence

import jax
import numpy as np

from .baseline import evaluate_baseline
from .cost_model import Metrics, evaluate, metrics_from_row
from .gemm import GEMM
from .mapping import candidate_mappings
from .memory import CiMSystemConfig
from .vectorized import (BASE_TILE_FIELDS, MAP_FIELDS, config_row,
                         enumerate_baseline_space, evaluate_baseline_flat,
                         evaluate_flat)

_EVAL_CIM = jax.jit(evaluate_flat)
_EVAL_BASE = jax.jit(evaluate_baseline_flat)

_OUT_KEYS = ("energy_pj", "time_ns", "compute_ns", "dram_ns", "smem_ns",
             "utilization", "dram_bytes", "smem_bytes", "valid")


def _gemm_key(g: GEMM):
    return (g.M, g.N, g.K, g.bits)


def _cfg_key(cfg: CiMSystemConfig):
    p = cfg.prim
    return (p.name, p.Rp, p.Cp, p.Rh, p.Ch, p.capacity_bytes, p.latency_ns,
            p.mac_energy_pj, cfg.cim_level, cfg.resolved_n_prims(),
            cfg.serialize_primitives, cfg.kn_balance_threshold)


def _pad_len(n: int) -> int:
    """Next power of two — bounds the number of jit retraces to O(log B)."""
    p = 1
    while p < n:
        p *= 2
    return p


def _run_padded(fn, batch: dict, n: int) -> dict:
    """jit-run a flat batch padded (by repeating row 0) to a pow2 length."""
    m = _pad_len(max(1, n))
    if m != n:
        batch = {k: np.concatenate(
            [v, np.broadcast_to(v[:1], (m - n,) + v.shape[1:])])
            for k, v in batch.items()}
    out = fn({k: np.asarray(v, np.float32) for k, v in batch.items()})
    return {k: np.asarray(out[k])[:n] for k in _OUT_KEYS}


class SweepEngine:
    """Whole-workload batched planner evaluation with an LRU result cache.

    cim_metrics / baseline_metrics return the same Metrics the scalar
    cost model produces (within float32 tolerance), but evaluate every
    uncached (GEMM, config) pair of a query in one fused device call.
    """

    def __init__(self, cache_size: int = 16384):
        self.cache_size = cache_size
        self._cache: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    # --- cache plumbing ---------------------------------------------------
    def _get(self, key):
        if key in self._cache:
            self._cache.move_to_end(key)
            self.hits += 1
            return self._cache[key]
        self.misses += 1
        return None

    def _put(self, key, value):
        self._cache[key] = value
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    def cache_info(self) -> dict:
        return {"size": len(self._cache), "max_size": self.cache_size,
                "hits": self.hits, "misses": self.misses}

    def cache_clear(self) -> None:
        self._cache.clear()
        self.hits = self.misses = 0

    # --- CiM options ------------------------------------------------------
    def cim_metrics(self, pairs: Sequence[tuple[GEMM, CiMSystemConfig]],
                    order_mode: str = "exact") -> list[Metrics]:
        """Metrics for each (GEMM, config) pair: the min-energy candidate
        mapping, scored on-device (== cost_model.evaluate)."""
        if order_mode != "exact":
            raise ValueError(
                "the batched sweep scores all DRAM orders in-kernel; only "
                "order_mode='exact' is supported (use backend='scalar' "
                "for greedy-order parity runs)")
        keys = [("cim", _gemm_key(g), _cfg_key(c), order_mode)
                for g, c in pairs]
        results: dict = {}
        todo: OrderedDict = OrderedDict()      # key -> (gemm, cfg)
        for key, (g, c) in zip(keys, pairs):
            hit = self._get(key)
            if hit is not None:
                results[key] = hit
            else:
                todo.setdefault(key, (g, c))

        if todo:
            flat, slices = [], []
            for key, (g, c) in todo.items():
                maps = candidate_mappings(g, c, order_mode)
                crow = config_row(c)
                start = len(flat)
                flat.extend(
                    {"M": g.M, "N": g.N, "K": g.K, **crow,
                     **{f: getattr(mp, f) for f in MAP_FIELDS}}
                    for mp in maps)
                slices.append((key, g, c, maps, start, start + len(maps)))
            batch = {f: np.asarray([r[f] for r in flat], np.float32)
                     for f in flat[0]}
            out = _run_padded(_EVAL_CIM, batch, len(flat))
            for key, g, c, maps, lo, hi in slices:
                e = out["energy_pj"][lo:hi]
                ok = out["valid"][lo:hi]
                if not ok.any():               # should not happen: mappings
                    met = evaluate(g, c, order_mode)   # are pre-validated
                else:
                    i = int(np.argmin(np.where(ok, e, np.inf)))
                    met = metrics_from_row(
                        g.ops, {k: out[k][lo + i] for k in _OUT_KEYS},
                        mapping=maps[i])
                self._put(key, met)
                results[key] = met
        return [results[k] for k in keys]

    # --- tensor-core baseline --------------------------------------------
    def baseline_metrics(self, gemms: Sequence[GEMM]) -> list[Metrics]:
        """Baseline Metrics per GEMM: the full tile grid scored on-device,
        lexicographic (time, energy) winner (== evaluate_baseline)."""
        keys = [("base", _gemm_key(g)) for g in gemms]
        results: dict = {}
        todo: OrderedDict = OrderedDict()
        for key, g in zip(keys, gemms):
            hit = self._get(key)
            if hit is not None:
                results[key] = hit
            else:
                todo.setdefault(key, g)

        if todo:
            spaces = [(key, g, enumerate_baseline_space(g))
                      for key, g in todo.items()]
            names = BASE_TILE_FIELDS + ("M", "N", "K")
            batch = {f: np.concatenate([np.asarray(s[f]) for _, _, s in
                                        spaces]) for f in names}
            n = batch["mt"].shape[0]
            out = _run_padded(_EVAL_BASE, batch, n)
            lo = 0
            for key, g, space in spaces:
                hi = lo + np.asarray(space["mt"]).shape[0]
                t = out["time_ns"][lo:hi]
                e = out["energy_pj"][lo:hi]
                ok = out["valid"][lo:hi]
                if not ok.any():
                    met = evaluate_baseline(g)
                else:
                    # lexicographic (time, energy), first index on ties —
                    # the scalar search's iteration-order tie-break
                    t = np.where(ok, t, np.inf)
                    tmin = t.min()
                    cand = np.where(t == tmin, np.where(ok, e, np.inf),
                                    np.inf)
                    i = int(np.argmin(cand))
                    met = metrics_from_row(
                        g.ops, {k: out[k][lo + i] for k in _OUT_KEYS})
                self._put(key, met)
                results[key] = met
                lo = hi
        return [results[k] for k in keys]


# Shared default engine: one process-wide cache, so the serving engine,
# benchmarks, and examples all reuse each other's results.
_ENGINE = SweepEngine()


def default_engine() -> SweepEngine:
    return _ENGINE


def cache_info() -> dict:
    return _ENGINE.cache_info()


def cache_clear() -> None:
    _ENGINE.cache_clear()


def jit_cache_clear() -> None:
    """Drop the compiled executables of the two fused kernels (the LRU
    *result* cache is untouched — use `cache_clear` for that).

    Benchmarks call this before a cold-jit measurement so the number is
    honest even when earlier code in the same process already traced the
    kernels (e.g. `benchmarks/run.py` runs other planner benches first).
    """
    _EVAL_CIM.clear_cache()
    _EVAL_BASE.clear_cache()


def sweep_evaluate(gemm: GEMM, cfg: CiMSystemConfig,
                   order_mode: str = "exact") -> Metrics:
    """Cached batched equivalent of cost_model.evaluate."""
    return _ENGINE.cim_metrics([(gemm, cfg)], order_mode)[0]


def sweep_evaluate_baseline(gemm: GEMM) -> Metrics:
    """Cached batched equivalent of baseline.evaluate_baseline."""
    return _ENGINE.baseline_metrics([gemm])[0]


def plan_workload_batched(gemms: Iterable[GEMM],
                          configs: dict[str, CiMSystemConfig] | None = None,
                          order_mode: str = "exact",
                          throughput_floor: float = 0.5,
                          engine: SweepEngine | None = None):
    """Batched planner.plan_workload: one device sweep, scalar verdicts.

    Evaluates all GEMMs x all configs x all candidate mappings in one
    fused call per kind (CiM / baseline), then applies exactly the same
    eligibility + "when" rules as planner.decide.
    """
    from .planner import make_decision, standard_configs
    engine = engine or _ENGINE
    gemms = list(gemms)
    configs = configs or standard_configs()
    names = list(configs)
    bases = engine.baseline_metrics(gemms)
    pairs = [(g, configs[name]) for g in gemms for name in names]
    mets = engine.cim_metrics(pairs, order_mode)
    decisions = []
    for i, g in enumerate(gemms):
        opts = {name: mets[i * len(names) + j]
                for j, name in enumerate(names)}
        decisions.append(make_decision(g, bases[i], opts, throughput_floor))
    return decisions


def decide_batched(gemm: GEMM,
                   configs: dict[str, CiMSystemConfig] | None = None,
                   order_mode: str = "exact",
                   throughput_floor: float = 0.5,
                   engine: SweepEngine | None = None):
    return plan_workload_batched([gemm], configs, order_mode,
                                 throughput_floor, engine)[0]
