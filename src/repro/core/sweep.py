"""Batched What/When/Where sweep engine (the planner's fast path).

`planner.decide` answers the paper's three questions one scalar cost-model
call at a time: every GEMM x 12 system configs x ~3 candidate mappings x 6
loop orders, plus a ~1300-point tensor-core baseline search, all in
Python.  This module flattens the whole workload — every GEMM, every
config, every candidate mapping — into two device batches (CiM rows and
baseline tile rows) and scores each under ONE `jax.jit` call through
`vectorized.evaluate_flat` / `evaluate_baseline_flat`.  CiMLoop-style
batched analytical evaluation is what makes full design-space sweeps
tractable; here it makes full-workload planning 10x+ faster than the
scalar path (benchmarks/sweep_bench.py tracks the ratio).

Results are memoized in an LRU cache keyed by (GEMM shape, system config,
order_mode), so repeated decode-shape queries — the serving engine asks
about the same handful of GEMMs for every session — are answered without
touching the device at all.  `cache_info()` exposes hit/miss telemetry.
The cache (and the compiled-kernel registry) is lock-protected: concurrent
`ServeSession.kernel_plan` builds may hammer one shared engine from many
threads.

Both order modes run fully batched: "exact" keeps the in-kernel min over
all 6 DRAM orders, "greedy" keeps each row's smallest-factor-outermost
order, also selected in-kernel (vectorized.evaluate_flat) — there is no
scalar fallback on any planner path.

Two CiM row kernels score those batches: the default XLA-fused path
(vectorized.evaluate_flat) and backend="pallas", a fused hand-written
kernel (repro.kernels.sweep_eval) consuming the same backend-shared cost
spec.  Pallas results live in their own result-cache keyspace, so parity
suites exercise the kernel rather than the LRU; on platforms whose
Pallas lowering is unavailable the engine transparently falls back to
the XLA kernel and records the reason in `cache_info()["pallas_fallback"]`
(which also carries a per-backend hit/miss breakdown).

Multi-device and multi-host scaling: an engine given a 1-D row mesh
(launch.mesh.row_mesh) shards every flattened row batch across the mesh
devices with `shard_map` — each row is independent, so
`exhaustive_best`-scale grids (tens of thousands of rows per workload)
split evenly over the row axis.  A mesh spanning several
`jax.distributed` processes (launch.distributed.global_row_mesh) runs the
same kernels pod-scale: every host enumerates the same grid SPMD,
materializes on device only the row shard its local devices own
(launch.distributed.host_local_to_global), and all-gathers only the
per-row output columns (_OUT_KEYS) for the replicated argmin/verdict
reduction — intermediate cost fields never cross hosts.  The default
engine auto-shards over all devices of an accelerator platform (the
global list: on a pod that is already every host's devices) and keeps the
plain single-device path when only one device exists (or on CPU, where
forced host-device counts are a debugging fiction, not parallel
hardware).

Streaming chunk enumerator: `SweepEngine(chunk_rows=N)` bounds device
memory per evaluation — the flattened grid is generated group by group
(a group = one query's candidate rows) and folded through the jitted
kernel in mesh-aligned tiles of at most N rows, with a cross-chunk
running reduction per group, so workload grids larger than one host's
memory stream through the engine.  Per-chunk accounting lands in
`cache_info()["chunks"]` (and, on a multi-host mesh,
`cache_info()["distributed"]` carries the process topology + row shard
balance).

Verdict parity with the scalar path is enforced by tests/test_sweep.py;
multi-process parity against the golden verdict fingerprint by
tests/test_distributed_sweep.py.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Iterable, Sequence

import jax
import numpy as np

from .baseline import evaluate_baseline
from .cost_model import Metrics, evaluate, metrics_from_row
from .gemm import GEMM
from .loopnest import check_order_mode
from .mapping import candidate_mappings
from .memory import CiMSystemConfig
from .vectorized import (BASE_TILE_FIELDS, MAP_FIELDS, config_row,
                         enumerate_baseline_space, evaluate_baseline_flat,
                         evaluate_flat, precision_row)

_OUT_KEYS = ("energy_pj", "time_ns", "compute_ns", "dram_ns", "smem_ns",
             "utilization", "dram_bytes", "smem_bytes", "valid")

# The result-cache/counter buckets a CiM query can resolve to, plus the
# baseline keyspace — cache_info()'s per-backend breakdown reports these.
CIM_BACKENDS = ("vectorized", "pallas")

# --- compiled-kernel registry ------------------------------------------------
# Every jitted sweep entry point — (kind, order_mode, mesh, kernel) —
# lives here, so jit_cache_clear() can drop *all* compiled executables: a
# "cold-jit" benchmark stays honest no matter which greedy/sharded/pallas
# variants earlier code in the process already traced.
_KERNEL_LOCK = threading.Lock()
_KERNELS: dict = {}


def _jit_kernel(kind: str, order_mode: str = "exact", mesh=None,
                kernel: str = "xla"):
    """Jitted evaluator for `kind` ("cim" | "base"), memoized per
    (order_mode, mesh, kernel).  kernel="xla" scores CiM rows through
    vectorized.evaluate_flat (XLA fusion of the 6-order unroll);
    kernel="pallas" through the fused hand-written kernel
    (repro.kernels.sweep_eval — same backend-shared cost spec, one
    pallas_call).  mesh=None is the single-device fast path; a 1-D row
    mesh wraps either kernel in shard_map over its row axis (rows are
    independent, so sharding is a pure data split — results are bitwise
    identical to the unsharded kernel)."""
    key = (kind, order_mode, mesh, kernel)
    with _KERNEL_LOCK:
        fn = _KERNELS.get(key)
        if fn is None:
            if kind == "cim" and kernel == "pallas":
                from ..kernels.sweep_eval import sweep_eval

                def base(batch, _om=order_mode):
                    return sweep_eval(batch, order_mode=_om)
            elif kind == "cim":
                def base(batch, _om=order_mode):
                    return evaluate_flat(batch, order_mode=_om)
            else:
                base = evaluate_baseline_flat
            if mesh is not None:
                from jax.experimental.shard_map import shard_map
                from jax.sharding import PartitionSpec
                axis = mesh.axis_names[0]
                # pallas_call has no shard_map replication rule; rows are
                # a pure data split (no cross-shard collectives), so
                # skipping the replication check is sound
                base = shard_map(base, mesh=mesh,
                                 in_specs=(PartitionSpec(axis),),
                                 out_specs=PartitionSpec(axis),
                                 check_rep=(kernel != "pallas"))
            fn = jax.jit(base)
            _KERNELS[key] = fn
    return fn


def _auto_mesh():
    """Row mesh over all devices when they are real parallel hardware;
    None (single-device path) for one device or CPU hosts
    (XLA_FLAGS-forced CPU device counts emulate topology, they don't add
    FLOPs — sharding tiny analytical batches over them only adds
    dispatch overhead).  jax.devices() is the GLOBAL list: in a
    jax.distributed multi-process job on accelerators the auto mesh
    already spans every host, and evaluation takes the multi-host path
    (global sharded inputs + output all-gather)."""
    devices = jax.devices()
    if len(devices) > 1 and devices[0].platform != "cpu":
        from ..launch.mesh import row_mesh
        return row_mesh(devices)
    return None


def _gemm_key(g: GEMM):
    return (g.M, g.N, g.K, g.bits, g.fp)


def _cfg_key(cfg: CiMSystemConfig):
    p = cfg.prim
    return (p.name, p.Rp, p.Cp, p.Rh, p.Ch, p.capacity_bytes, p.latency_ns,
            p.mac_energy_pj, cfg.cim_level, cfg.resolved_n_prims(),
            cfg.serialize_primitives, cfg.kn_balance_threshold)


def _pad_len(n: int, shards: int = 1) -> int:
    """Next power of two (bounds jit retraces to O(log B)), rounded up to
    a multiple of the shard count so the row axis splits evenly."""
    p = 1
    while p < n:
        p *= 2
    if shards > 1:
        p = -(-p // shards) * shards
    return p


def _mesh_is_multihost(mesh) -> bool:
    """Does `mesh` contain devices of other jax.distributed processes?
    (Local duplicate of launch.distributed.is_multihost so the hot path
    needs no launch import on the common single-host mesh.)"""
    if mesh is None:
        return False
    pi = jax.process_index()
    return any(d.process_index != pi for d in mesh.devices.flat)


def _run_padded(fn, batch: dict, n: int, shards: int = 1,
                mesh=None) -> dict:
    """jit-run a flat batch padded (by repeating row 0) to a pow2 length
    (multiple of `shards` when the kernel is row-sharded).

    On a multi-host mesh each process feeds the kernel global arrays of
    which it materializes only its addressable row shard, and the per-row
    output columns — only those — are all-gathered back so every host
    can run the identical reduction (launch.distributed)."""
    m = _pad_len(max(1, n), shards)
    if m != n:
        batch = {k: np.concatenate(
            [v, np.broadcast_to(v[:1], (m - n,) + v.shape[1:])])
            for k, v in batch.items()}
    arrs = {k: np.asarray(v, np.float32) for k, v in batch.items()}
    if _mesh_is_multihost(mesh):
        from ..launch import distributed as dist
        out = fn(dist.host_local_to_global(arrs, mesh))
        out = dist.gather_rows({k: out[k] for k in _OUT_KEYS})
    else:
        out = fn(arrs)
    return {k: np.asarray(out[k])[:n] for k in _OUT_KEYS}


def _cat_cols(parts: list[dict]) -> dict:
    """Concatenate columnar row-group slices into one flat batch."""
    if len(parts) == 1:
        return dict(parts[0])
    return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}


def _iter_chunks(groups, chunk_rows: int | None):
    """The streaming enumerator: walk `groups` — an iterable of
    (gid, cols) where cols is a dict of equal-length (n,) numpy columns —
    and yield evaluation tiles of at most `chunk_rows` rows.

    Yields (batch, segments): `batch` is the concatenated columns,
    `segments` is [(gid, group_offset, lo, hi)] mapping each slice of the
    tile back to its group (a group larger than a tile spans several
    tiles; the caller folds segments through a running per-group
    reduction).  chunk_rows=None degenerates to one tile holding
    everything — the classic whole-batch path.  Groups are consumed
    lazily, so grids larger than host memory stream through as long as
    each *group* fits.
    """
    parts: list[dict] = []
    segs: list[tuple] = []
    filled = 0
    for gid, cols in groups:
        n = len(next(iter(cols.values())))
        off = 0
        while off < n:
            take = (n - off if chunk_rows is None
                    else min(n - off, chunk_rows - filled))
            parts.append({k: v[off:off + take] for k, v in cols.items()})
            segs.append((gid, off, filled, filled + take))
            filled += take
            off += take
            if chunk_rows is not None and filled >= chunk_rows:
                yield _cat_cols(parts), segs
                parts, segs, filled = [], [], 0
    if filled:
        yield _cat_cols(parts), segs


class SweepEngine:
    """Whole-workload batched planner evaluation with an LRU result cache.

    cim_metrics / baseline_metrics return the same Metrics the scalar
    cost model produces (within float32 tolerance), but evaluate every
    uncached (GEMM, config) pair of a query in one fused device call.

    mesh: "auto" (default) shards row batches over all accelerator
    devices when more than one exists (single-device fast path
    otherwise); None forces the unsharded path; an explicit 1-D mesh
    (launch.mesh.row_mesh) is always honored — including a 1-device mesh,
    which exercises the shard_map path for parity testing, and a
    multi-host mesh (launch.distributed.global_row_mesh), which takes the
    global-array + output-all-gather path.

    chunk_rows: None (default) evaluates each query batch in one device
    call; an integer bounds every call to at most that many rows — the
    flattened grid streams through the kernel in mesh-aligned tiles with
    a cross-chunk running reduction per query, so grids larger than one
    host's device memory still evaluate (and every chunk lands in the
    LRU/telemetry accounting as it completes).  Results are bitwise
    identical either way: rows are evaluated elementwise, and the
    reductions preserve first-index tie-breaks across tiles.

    All cache mutations (and the hit/miss counters) are serialized by a
    per-engine lock: the process-wide default engine is shared by every
    ServeSession.kernel_plan build, which may run on concurrent threads.
    """

    def __init__(self, cache_size: int = 16384, mesh="auto",
                 chunk_rows: int | None = None):
        if chunk_rows is not None and chunk_rows < 1:
            raise ValueError(f"chunk_rows must be >= 1 or None, "
                             f"got {chunk_rows}")
        self.cache_size = cache_size
        self.chunk_rows = chunk_rows
        self._mesh = mesh
        self._cache: OrderedDict = OrderedDict()
        self._lock = threading.RLock()
        self._local = threading.local()   # per-thread hit/miss counters
        self.hits = 0
        self.misses = 0
        # per-backend keyspace breakdown ("vectorized" / "pallas" /
        # "baseline") + the recorded reason if a pallas request ever fell
        # back to the XLA kernel on this engine
        self._backend_counts: dict = {}
        self._pallas_fallback: str | None = None
        # streaming-enumerator accounting (cache_info()["chunks"])
        self._chunks_evaluated = 0
        self._rows_evaluated = 0
        self._rows_padded = 0

    @property
    def mesh(self):
        """The resolved row mesh (lazy: "auto" queries jax.devices() on
        first evaluation, not at construction/import time)."""
        if self._mesh == "auto":
            self._mesh = _auto_mesh()
        return self._mesh

    @property
    def n_shards(self) -> int:
        return self.mesh.size if self.mesh is not None else 1

    # --- cache plumbing ---------------------------------------------------
    def _get(self, key, bucket: str):
        with self._lock:
            counts = self._backend_counts.setdefault(
                bucket, {"hits": 0, "misses": 0})
            if key in self._cache:
                self._cache.move_to_end(key)
                self.hits += 1
                counts["hits"] += 1
                self._local.hits = getattr(self._local, "hits", 0) + 1
                return self._cache[key]
            self.misses += 1
            counts["misses"] += 1
            self._local.misses = getattr(self._local, "misses", 0) + 1
            return None

    def thread_cache_counts(self) -> tuple[int, int]:
        """(hits, misses) accrued by the CALLING thread only — monotonic,
        unaffected by cache_clear.  Lets telemetry attribute a plan
        build's lookups to that build without locking out concurrent
        builds or counting their traffic (measured_cache_delta)."""
        tl = self._local
        return getattr(tl, "hits", 0), getattr(tl, "misses", 0)

    def _put(self, key, value):
        with self._lock:
            self._cache[key] = value
            self._cache.move_to_end(key)
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)

    def cache_info(self) -> dict:
        """Size + hit/miss totals, the per-backend breakdown (which
        keyspace — vectorized / pallas / baseline — each lookup resolved
        to), `pallas_fallback` (None normally, the recorded lowering
        error if a backend="pallas" request ever fell back to the XLA
        kernel), the streaming-enumerator accounting under "chunks"
        (tiles evaluated / real vs padding rows), and — on a multi-host
        mesh — a "distributed" block with the process topology and the
        cumulative per-process row shard balance.  Serve/dryrun telemetry
        embed this dict verbatim (launch.report renders it)."""
        with self._lock:
            info = {"size": len(self._cache), "max_size": self.cache_size,
                    "hits": self.hits, "misses": self.misses,
                    "backends": {b: dict(c) for b, c in
                                 self._backend_counts.items()},
                    "pallas_fallback": self._pallas_fallback,
                    "chunks": {"chunk_rows": self.chunk_rows,
                               "evaluated": self._chunks_evaluated,
                               "rows": self._rows_evaluated,
                               "padded_rows": self._rows_padded},
                    "distributed": None}
        if _mesh_is_multihost(self.mesh):
            from ..launch import distributed as dist
            total = info["chunks"]["rows"] + info["chunks"]["padded_rows"]
            info["distributed"] = {
                **dist.distributed_info(),
                "mesh_devices": self.mesh.size,
                "shard_balance": dist.shard_balance(total, self.mesh),
            }
        return info

    def cache_clear(self) -> None:
        # _pallas_fallback survives on purpose: it records a platform
        # property of this process, not cache state
        with self._lock:
            self._cache.clear()
            self.hits = self.misses = 0
            self._backend_counts = {}
            self._chunks_evaluated = 0
            self._rows_evaluated = 0
            self._rows_padded = 0

    # --- streaming evaluation --------------------------------------------
    def _stream_batches(self, fn, groups, update) -> None:
        """Fold a lazily-enumerated grid through the jitted kernel.

        groups: iterable of (gid, cols) — see `_iter_chunks`.  Every tile
        is padded/mesh-aligned and evaluated in ONE device call
        (`_run_padded`, which takes the global-array path on a multi-host
        mesh); `update(gid, group_offset, out, lo, hi)` folds each tile
        segment into the caller's running per-group reduction.  Per-tile
        accounting lands in the "chunks" telemetry.
        """
        shards = self.n_shards
        mesh = self.mesh
        for cols, segs in _iter_chunks(groups, self.chunk_rows):
            n = len(next(iter(cols.values())))
            out = _run_padded(fn, cols, n, shards, mesh)
            with self._lock:
                self._chunks_evaluated += 1
                self._rows_evaluated += n
                self._rows_padded += _pad_len(max(1, n), shards) - n
            for gid, off, lo, hi in segs:
                update(gid, off, out, lo, hi)

    # --- CiM options ------------------------------------------------------
    def _resolve_cim_backend(self, backend: str) -> tuple[str, str]:
        """(kernel, bucket) for a CiM query: `kernel` in {"xla","pallas"}
        picks the jitted entry point, `bucket` names the result-cache
        keyspace (and per-backend counters).  A "pallas" request on a
        platform whose Pallas lowering is unavailable falls back to the
        XLA kernel — and to the shared "vectorized" keyspace, since the
        results are then literally the vectorized backend's — recording
        the reason for cache_info()/telemetry."""
        if backend not in CIM_BACKENDS:
            raise ValueError(f"unknown sweep backend {backend!r}; "
                             f"expected one of {CIM_BACKENDS}")
        if backend == "pallas":
            from ..kernels.sweep_eval import pallas_status
            status = pallas_status()
            if status["mode"] == "unavailable":
                with self._lock:
                    self._pallas_fallback = status["reason"]
                return "xla", "vectorized"
            return "pallas", "pallas"
        return "xla", "vectorized"

    def cim_metrics(self, pairs: Sequence[tuple[GEMM, CiMSystemConfig]],
                    order_mode: str = "exact",
                    backend: str = "vectorized") -> list[Metrics]:
        """Metrics for each (GEMM, config) pair: the min-energy candidate
        mapping, scored on-device (== cost_model.evaluate).  Both order
        modes run in-kernel — "exact" takes the min over all 6 DRAM
        orders, "greedy" selects each row's smallest-factor-outermost
        order (no scalar fallback).  backend="pallas" routes the batch
        through the fused Pallas kernel (distinct result-cache keyspace,
        so backend parity tests measure the kernel, not the LRU); when
        its lowering is unavailable the query falls back to the XLA
        kernel with the reason recorded in cache_info()."""
        check_order_mode(order_mode)
        kernel, bucket = self._resolve_cim_backend(backend)
        keys = [("cim", bucket, _gemm_key(g), _cfg_key(c), order_mode)
                for g, c in pairs]
        results: dict = {}
        todo: OrderedDict = OrderedDict()      # key -> (gemm, cfg)
        for key, (g, c) in zip(keys, pairs):
            hit = self._get(key, bucket)
            if hit is not None:
                results[key] = hit
            else:
                todo.setdefault(key, (g, c))

        if todo:
            fn = _jit_kernel("cim", order_mode, self.mesh, kernel)
            best: dict = {}          # key -> [energy, out_row, mapping]
            # candidate lists of groups still in flight (some rows not
            # yet reduced) — dropped as soon as a group completes, so
            # host memory holds O(chunk) mappings, not the whole grid
            live: dict = {}          # key -> [maps, rows_remaining]

            def groups():
                # the streaming enumerator: candidate mappings are
                # generated per query as tiles fill, never all at once
                for key, (g, c) in todo.items():
                    maps = candidate_mappings(g, c, order_mode)
                    live[key] = [maps, len(maps)]
                    crow = {"M": g.M, "N": g.N, "K": g.K,
                            **precision_row(g), **config_row(c)}
                    cols = {f: np.full(len(maps), float(v), np.float32)
                            for f, v in crow.items()}
                    for f in MAP_FIELDS:
                        cols[f] = np.asarray(
                            [getattr(mp, f) for mp in maps], np.float32)
                    yield key, cols

            def update(key, off, out, lo, hi):
                # min-energy valid row; strict < keeps the first index on
                # ties, within a tile (np.argmin) and across tiles alike
                entry = live[key]
                e = np.where(out["valid"][lo:hi],
                             out["energy_pj"][lo:hi], np.inf)
                i = int(np.argmin(e))
                st = best.get(key)
                if np.isfinite(e[i]) and (st is None or e[i] < st[0]):
                    best[key] = [e[i], {k: out[k][lo + i]
                                        for k in _OUT_KEYS},
                                 entry[0][off + i]]
                entry[1] -= hi - lo
                if entry[1] == 0:              # group fully reduced
                    del live[key]

            self._stream_batches(fn, groups(), update)
            for key, (g, c) in todo.items():
                st = best.get(key)
                if st is None:                 # should not happen: mappings
                    met = evaluate(g, c, order_mode)   # are pre-validated
                else:
                    met = metrics_from_row(g.ops, st[1], mapping=st[2])
                self._put(key, met)
                results[key] = met
        return [results[k] for k in keys]

    # --- tensor-core baseline --------------------------------------------
    def baseline_metrics(self, gemms: Sequence[GEMM]) -> list[Metrics]:
        """Baseline Metrics per GEMM: the full tile grid scored on-device,
        lexicographic (time, energy) winner (== evaluate_baseline)."""
        keys = [("base", _gemm_key(g)) for g in gemms]
        results: dict = {}
        todo: OrderedDict = OrderedDict()
        for key, g in zip(keys, gemms):
            hit = self._get(key, "baseline")
            if hit is not None:
                results[key] = hit
            else:
                todo.setdefault(key, g)

        if todo:
            fn = _jit_kernel("base", mesh=self.mesh)
            names = BASE_TILE_FIELDS + ("M", "N", "K")
            best: dict = {}          # key -> [time, energy, out_row]

            def groups():
                # one group per GEMM's full tile grid (the ~1300-point
                # search space), enumerated lazily as tiles fill
                for key, g in todo.items():
                    space = enumerate_baseline_space(g)
                    yield key, {f: np.asarray(space[f], np.float32)
                                for f in names}

            def update(key, off, out, lo, hi):
                # lexicographic (time, energy) among valid rows, first
                # index on ties — the scalar search's iteration-order
                # tie-break.  Strict-improvement replacement preserves it
                # across tiles (earlier tiles hold earlier rows).
                ok = out["valid"][lo:hi]
                t = np.where(ok, out["time_ns"][lo:hi], np.inf)
                tmin = t.min()
                if not np.isfinite(tmin):
                    return                       # no valid row in segment
                cand = np.where(t == tmin,
                                np.where(ok, out["energy_pj"][lo:hi],
                                         np.inf), np.inf)
                i = int(np.argmin(cand))
                st = best.get(key)
                if (st is None or tmin < st[0]
                        or (tmin == st[0] and cand[i] < st[1])):
                    best[key] = [tmin, cand[i],
                                 {k: out[k][lo + i] for k in _OUT_KEYS}]

            self._stream_batches(fn, groups(), update)
            for key, g in todo.items():
                st = best.get(key)
                if st is None:
                    met = evaluate_baseline(g)
                else:
                    met = metrics_from_row(g.ops, st[2])
                self._put(key, met)
                results[key] = met
        return [results[k] for k in keys]


# Shared default engine: one process-wide cache, so the serving engine,
# benchmarks, and examples all reuse each other's results.
_ENGINE = SweepEngine()


def default_engine() -> SweepEngine:
    return _ENGINE


def cache_info() -> dict:
    return _ENGINE.cache_info()


def cache_clear() -> None:
    _ENGINE.cache_clear()


def jit_cache_clear() -> None:
    """Drop the compiled executables of EVERY jitted sweep kernel — all
    (kind, order_mode, mesh, kernel) entry points in the registry, so
    greedy, sharded and pallas variants go cold too (the LRU *result*
    cache is untouched — use `cache_clear` for that).

    Benchmarks call this before a cold-jit measurement so the number is
    honest even when earlier code in the same process already traced the
    kernels (e.g. `benchmarks/run.py` runs other planner benches first).
    """
    with _KERNEL_LOCK:
        for fn in _KERNELS.values():
            fn.clear_cache()


def jit_kernel_count() -> int:
    """Number of live compiled executables across every registered sweep
    kernel (0 right after jit_cache_clear) — benchmark/test telemetry.

    `_cache_size` is a private jax attribute; if a future jax drops it,
    unknown kernels count as 0 rather than crashing telemetry callers."""
    with _KERNEL_LOCK:
        total = 0
        for fn in _KERNELS.values():
            size = getattr(fn, "_cache_size", None)
            if size is not None:
                total += size()
        return total


def measured_cache_delta(fn):
    """Run `fn()` (a plan build against the default engine) and return
    (result, telemetry): the default engine's hit/miss delta attributed
    to this call, plus the engine-wide totals.

    Shared by ServeSession.kernel_plan and the dry-run decode cells so
    the telemetry schema can't drift between reports.  Attribution uses
    the engine's per-thread counters, so concurrent measured builds
    neither serialize behind each other nor contaminate each other's
    deltas (`fn` must do its engine queries on the calling thread, which
    plan_workload does).
    """
    h0, m0 = _ENGINE.thread_cache_counts()
    result = fn()
    h1, m1 = _ENGINE.thread_cache_counts()
    return result, {
        "plan_hits": h1 - h0,
        "plan_misses": m1 - m0,
        "engine": _ENGINE.cache_info(),
    }


def sweep_evaluate(gemm: GEMM, cfg: CiMSystemConfig,
                   order_mode: str = "exact") -> Metrics:
    """Cached batched equivalent of cost_model.evaluate."""
    return _ENGINE.cim_metrics([(gemm, cfg)], order_mode)[0]


def sweep_evaluate_baseline(gemm: GEMM) -> Metrics:
    """Cached batched equivalent of baseline.evaluate_baseline."""
    return _ENGINE.baseline_metrics([gemm])[0]


def plan_workload_batched(gemms: Iterable[GEMM],
                          configs: dict[str, CiMSystemConfig] | None = None,
                          order_mode: str = "exact",
                          throughput_floor: float = 0.5,
                          engine: SweepEngine | None = None,
                          backend: str = "vectorized"):
    """Batched planner.plan_workload: one device sweep, scalar verdicts.

    Evaluates all GEMMs x all configs x all candidate mappings in one
    fused call per kind (CiM / baseline), then applies exactly the same
    eligibility + "when" rules as planner.decide.  backend selects the
    CiM row kernel ("vectorized" = XLA-fused evaluate_flat, "pallas" =
    the fused hand-written kernel); the tensor-core baseline sweep always
    runs on the XLA kernel — its 36-permutation search is outside the
    Pallas tentpole and shared by both backends, so verdicts can only
    differ through the CiM rows.
    """
    from .planner import make_decision, standard_configs
    engine = engine or _ENGINE
    gemms = list(gemms)
    configs = configs or standard_configs()
    names = list(configs)
    bases = engine.baseline_metrics(gemms)
    pairs = [(g, configs[name]) for g in gemms for name in names]
    mets = engine.cim_metrics(pairs, order_mode, backend)
    decisions = []
    for i, g in enumerate(gemms):
        opts = {name: mets[i * len(names) + j]
                for j, name in enumerate(names)}
        decisions.append(make_decision(g, bases[i], opts, throughput_floor))
    return decisions


def decide_batched(gemm: GEMM,
                   configs: dict[str, CiMSystemConfig] | None = None,
                   order_mode: str = "exact",
                   throughput_floor: float = 0.5,
                   engine: SweepEngine | None = None,
                   backend: str = "vectorized"):
    return plan_workload_batched([gemm], configs, order_mode,
                                 throughput_floor, engine, backend)[0]
