"""Tensor-core-like baseline architecture model (paper §V-A).

One SM: 4 sub-cores x 16x16 PEs (1024 INT8 MACs/cycle @ 1 GHz, peak
2048 GOPS), RF 4x4 KB, SMEM 256 KB, DRAM.  Unlike CiM, the baseline is
*not* forced weight-stationary: tile sizes and per-level loop orders are
searched (cuBLAS-style), which is exactly the flexibility the paper credits
for its better behaviour on small-M GEMMs (§VI-C).

Dataflow modelled: output-stationary at the PE level (psums in PE
registers while K streams), A/W/Z tiles staged in RF, super-tiles in SMEM.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from .cost_model import Metrics
from .gemm import GEMM
from .loopnest import Loop, ceil_div, coverage_factor, revisit_factor
from .mapping import PSUM_BYTES
from .memory import DRAM, RF, SMEM, TEMPORAL_REDUCTION_PJ
from .primitives import TENSOR_CORE, TensorCoreSpec

# spatial extent of the PE grid: 4 subcores arranged 2x2 -> 32x32 outputs
SPATIAL_M = 32
SPATIAL_N = 32


@dataclasses.dataclass(frozen=True)
class BaselineMapping:
    gemm: GEMM
    mt: int                      # RF tile (outputs mt x nt, depth kt)
    nt: int
    kt: int
    ms: int                      # SMEM super-tile factors (in RF tiles)
    ns: int
    ks: int
    rf_loops: tuple[Loop, ...]   # innermost first
    smem_loops: tuple[Loop, ...]
    dram_loops: tuple[Loop, ...]

    def validate(self) -> None:
        g = self.gemm
        rf_bytes = (self.mt * self.kt + self.kt * self.nt
                    + self.mt * self.nt * PSUM_BYTES)
        assert rf_bytes <= RF.capacity_bytes, self
        sm_m, sm_n, sm_k = (self.mt * self.ms, self.nt * self.ns,
                            self.kt * self.ks)
        smem_bytes = (min(g.M, sm_m) * min(g.K, sm_k)
                      + min(g.K, sm_k) * min(g.N, sm_n)
                      + min(g.M, sm_m) * min(g.N, sm_n) * PSUM_BYTES)
        assert smem_bytes <= SMEM.capacity_bytes, self


def _evaluate_order(mp: BaselineMapping, spec: TensorCoreSpec = TENSOR_CORE
                    ) -> Metrics:
    g = mp.gemm
    mt, nt, kt = min(g.M, mp.mt), min(g.N, mp.nt), min(g.K, mp.kt)
    sm_m = min(g.M, mp.mt * mp.ms)
    sm_n = min(g.N, mp.nt * mp.ns)
    sm_k = min(g.K, mp.kt * mp.ks)

    above_rf = list(mp.smem_loops) + list(mp.dram_loops)
    above_smem = list(mp.dram_loops)

    e = {}
    # ---- DRAM -> SMEM ------------------------------------------------------
    a_fills = max(sm_m * sm_k * revisit_factor(above_smem, "A"),
                  g.input_elems)
    w_fills = max(sm_k * sm_n * revisit_factor(above_smem, "W"),
                  g.weight_elems)
    rz = revisit_factor(above_smem, "Z")
    cz = coverage_factor(above_smem, "Z")
    z_spill = sm_m * sm_n * max(0, rz - cz)
    z_dram = sm_m * sm_n * cz + 2 * z_spill * PSUM_BYTES
    dram_bytes = a_fills + w_fills + max(z_dram, g.output_elems)
    e["dram"] = DRAM.energy_pj(dram_bytes)

    # ---- SMEM -> RF ----------------------------------------------------------
    a_rf = max(mt * kt * revisit_factor(above_rf, "A"), g.input_elems)
    w_rf = max(kt * nt * revisit_factor(above_rf, "W"), g.weight_elems)
    rzr = revisit_factor(above_rf, "Z")
    czr = coverage_factor(above_rf, "Z")
    z_rf = (mt * nt * czr
            + 2 * mt * nt * max(0, rzr - czr) * PSUM_BYTES)
    smem_bytes = a_rf + w_rf + z_rf
    e["smem"] = SMEM.energy_pj(smem_bytes)

    # ---- RF -> PE operand collectors -----------------------------------------
    # Every MAC reads both operands from the register file through the
    # operand collectors (no cross-PE amortization — GPU-style register
    # operand reads).  These are exactly the accesses CiM's stationarity
    # eliminates (paper §VI-C "saving the data accesses in the lower memory
    # levels").  Psums stay in PE accumulators across kt.
    macs = g.macs
    rf_reads = 2.0 * macs
    z_rf_rmw = 2.0 * g.output_elems * ceil_div(g.K, kt) * PSUM_BYTES
    e["rf"] = RF.energy_pj(rf_reads + z_rf_rmw)

    # per-MAC operand feeds from the PE operand buffers
    e["pe_buffer"] = 2.0 * macs * spec.pe_buffer_energy_pj
    e["mac"] = macs * spec.mac_energy_pj
    adds = g.output_elems * max(0, ceil_div(g.K, kt) - 1)
    e["reduction"] = adds * TEMPORAL_REDUCTION_PJ
    energy = sum(e.values())

    # ---- time ----------------------------------------------------------------
    # spatial utilization of the 32x32 grid given the RF tile
    eff_m = mt / (ceil_div(mt, SPATIAL_M) * SPATIAL_M)
    eff_n = nt / (ceil_div(nt, SPATIAL_N) * SPATIAL_N)
    util = eff_m * eff_n
    compute_ns = macs / (spec.macs_per_cycle * max(util, 1e-9)) \
        / spec.freq_ghz
    dram_ns = dram_bytes / DRAM.bandwidth_bytes_per_cycle
    smem_ns = smem_bytes / SMEM.bandwidth_bytes_per_cycle
    time_ns = max(compute_ns, dram_ns, smem_ns)

    return Metrics(ops=g.ops, energy_pj=energy, time_ns=time_ns,
                   compute_ns=compute_ns, dram_ns=dram_ns, smem_ns=smem_ns,
                   utilization=util, dram_bytes=dram_bytes,
                   smem_bytes=smem_bytes, energy_breakdown_pj=e, mapping=mp)


def _pow2s(limit: int, lo: int = 1):
    v = lo
    while v <= limit:
        yield v
        v *= 2


def tile_candidates(gemm: GEMM):
    """Yield every (mt, nt, kt, ms, ns, ks) tile combo the baseline search
    considers: the power-of-two RF tile grid, the largest K depth fitting
    RF, and greedily-grown SMEM super-tile factors (M first, then N, then
    K).  Shared by the scalar search below and the batched scorer in
    vectorized.evaluate_baseline_flat (same order, so tie-breaks agree).
    """
    g = gemm
    for mt in _pow2s(min(2 * SPATIAL_M * 4, max(SPATIAL_M, g.M)), 8):
        for nt in _pow2s(min(2 * SPATIAL_N * 4, max(SPATIAL_N, g.N)), 8):
            # largest power-of-two K depth that fits RF with these tiles
            rem = RF.capacity_bytes - mt * nt * PSUM_BYTES
            if rem <= 0:
                continue
            kt = 1
            while (mt + nt) * kt * 2 <= rem and kt < g.K:
                kt *= 2
            kt = min(kt, max(1, g.K))
            # SMEM super-tile: grow factors greedily, M first then N
            ms = ns = ks = 1

            def smem_ok(ms, ns, ks):
                return (min(g.M, mt * ms) * min(g.K, kt * ks)
                        + min(g.K, kt * ks) * min(g.N, nt * ns)
                        + min(g.M, mt * ms) * min(g.N, nt * ns) * PSUM_BYTES
                        ) <= SMEM.capacity_bytes
            while mt * ms < g.M and smem_ok(ms * 2, ns, ks):
                ms *= 2
            while nt * ns < g.N and smem_ok(ms, ns * 2, ks):
                ns *= 2
            while kt * ks < g.K and smem_ok(ms, ns, ks * 2):
                ks *= 2
            yield (mt, nt, kt, ms, ns, ks)


def evaluate_baseline(gemm: GEMM, spec: TensorCoreSpec = TENSOR_CORE
                      ) -> Metrics:
    """Search tile sizes + loop orders for the tensor-core baseline and
    return the best (min cycles, then min energy) metrics.

    This is the scalar reference; repro.core.sweep scores the identical
    grid through vectorized.evaluate_baseline_flat in one fused kernel.
    """
    g = gemm
    best: Metrics | None = None
    for mt, nt, kt, ms, ns, ks in tile_candidates(g):
        rf_loops = (("M", ms), ("K", ks), ("N", ns))
        dram = (("M", ceil_div(g.M, mt * ms)),
                ("K", ceil_div(g.K, kt * ks)),
                ("N", ceil_div(g.N, nt * ns)))
        for rf_perm in itertools.permutations(rf_loops):
            for dram_perm in itertools.permutations(dram):
                mp = BaselineMapping(g, mt, nt, kt, ms, ns, ks,
                                     rf_loops=tuple(rf_perm),
                                     smem_loops=tuple(rf_perm),
                                     dram_loops=tuple(dram_perm))
                try:
                    mp.validate()
                except AssertionError:
                    continue
                m = _evaluate_order(mp, spec)
                key = (m.time_ns, m.energy_pj)
                if best is None or key < (best.time_ns, best.energy_pj):
                    best = m
    assert best is not None, f"no valid baseline mapping for {gemm}"
    return best
