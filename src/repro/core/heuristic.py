"""Heuristic (random-search) mapper baseline (paper §IV-B, Fig. 7/Table II).

Timeloop-style random sampling over the raw mapping space: factor tuples
are drawn uniformly from power-of-two grids *including invalid points*;
the search terminates after `max_consecutive_invalid` invalid samples in a
row (the paper uses 100 000) or after `max_valid` scored samples.

The paper's point (which this reproduces) is that the priority mapper gets
equal-or-better mappings with no search, because the search is agnostic to
the CiM primitive's inherent reuse structure.
"""
from __future__ import annotations

import dataclasses
import random

from .cost_model import Metrics, evaluate_cim
from .gemm import GEMM
from .loopnest import ceil_div
from .mapping import PSUM_BYTES, CiMMapping
from .memory import SMEM, CiMSystemConfig


def _pow2_choices(limit: int) -> list[int]:
    out, v = [], 1
    while v <= limit:
        out.append(v)
        v *= 2
    return out


@dataclasses.dataclass
class SearchResult:
    best: Metrics | None
    sampled: int
    valid: int
    consecutive_invalid_stop: bool


def random_search(gemm: GEMM, cfg: CiMSystemConfig, *,
                  seed: int = 0,
                  max_consecutive_invalid: int = 100_000,
                  max_valid: int = 2_000,
                  objective: str = "edp") -> SearchResult:
    rng = random.Random(seed)
    p = cfg.prim
    n_prims = cfg.resolved_n_prims()
    k_choices = _pow2_choices(min(gemm.K, p.k_rows))
    n_choices = _pow2_choices(min(gemm.N, p.n_cols))
    pk_choices = list(range(1, n_prims + 1))
    m_choices = _pow2_choices(gemm.M)
    f_choices = _pow2_choices(4096)
    dims = ["M", "N", "K"]

    best: Metrics | None = None
    invalid_run = 0
    sampled = valid = 0
    stop_invalid = False
    while True:
        sampled += 1
        k_arr = rng.choice(k_choices)
        n_arr = rng.choice(n_choices)
        pk = rng.choice(pk_choices)
        pn = rng.choice(pk_choices)
        m1 = rng.choice(m_choices)
        fk = rng.choice(f_choices)
        fn = rng.choice(f_choices)
        order = dims[:]
        rng.shuffle(order)
        k_tiles = ceil_div(gemm.K, max(1, k_arr * pk))
        n_tiles = ceil_div(gemm.N, max(1, n_arr * pn))
        loops = tuple({"M": ("M", ceil_div(gemm.M, m1)),
                       "K": ("K", ceil_div(k_tiles, fk)),
                       "N": ("N", ceil_div(n_tiles, fn))}[d] for d in order)
        mp = CiMMapping(gemm=gemm, cfg=cfg, k_arr=k_arr, n_arr=n_arr,
                        pk=pk, pn=pn, m1=m1, fk=fk, fn=fn, dram_loops=loops)
        try:
            mp.validate()
        except AssertionError:
            invalid_run += 1
            if invalid_run >= max_consecutive_invalid:
                stop_invalid = True
                break
            continue
        invalid_run = 0
        valid += 1
        m = evaluate_cim(mp, order_mode="greedy")
        if best is None or _score(m, objective) < _score(best, objective):
            best = m
        if valid >= max_valid:
            break
    return SearchResult(best=best, sampled=sampled, valid=valid,
                        consecutive_invalid_stop=stop_invalid)


def _score(m: Metrics, objective: str) -> float:
    if objective == "energy":
        return m.energy_pj
    if objective == "time":
        return m.time_ns
    return m.edp
