"""Workload GEMM datasets (paper §V-C, Table VI, Appendix B).

Real dataset: ResNet50/ImageNet, BERT-Large (seq 512), DLRM, GPT-J decode —
transcribed from Table VI.  Synthetic dataset: 1000 GEMMs with M, N, K in
[16, 8192] (paper Fig. 9).
"""
from __future__ import annotations

import random

from .gemm import GEMM

# --- Table VI (exact transcription; repeated layers keep their multiplicity)

BERT_LARGE = [
    GEMM(512, 1024, 1024, label="BERT-Large QKV/O proj"),
    GEMM(512, 512, 1024, label="BERT-Large logit/attend"),
    GEMM(512, 1024, 512, label="BERT-Large attn out"),
    GEMM(512, 4096, 1024, label="BERT-Large FFN up"),
    GEMM(512, 1024, 4096, label="BERT-Large FFN down"),
]

GPT_J = [
    GEMM(1, 4096, 4096, label="GPT-J decode proj"),
    GEMM(2048, 4096, 4096, label="GPT-J prefill proj"),
    GEMM(1, 2048, 4096, label="GPT-J decode down"),
    GEMM(1, 4096, 2048, label="GPT-J decode up"),
    GEMM(1, 16384, 4096, label="GPT-J decode FFN"),
]

DLRM = [
    GEMM(1, 256, 512, label="DLRM MLP"),
    GEMM(1, 64, 256, label="DLRM MLP"),
]

_RESNET50_ROWS = [
    (12544, 64, 147, 1), (3136, 64, 64, 1), (3136, 64, 576, 3),
    (3136, 256, 64, 3), (3136, 64, 256, 3), (3136, 128, 256, 1),
    (784, 128, 1152, 4), (784, 512, 128, 4), (784, 128, 512, 4),
    (784, 256, 512, 1), (196, 256, 2304, 6), (196, 1024, 256, 6),
    (196, 256, 1024, 6), (196, 512, 1024, 1), (49, 512, 4608, 3),
    (49, 2048, 512, 3), (49, 512, 2048, 3), (1, 1000, 2048, 1),
]

RESNET50 = [GEMM(m, n, k, label=f"ResNet50 {m}x{n}x{k}", count=c)
            for (m, n, k, c) in _RESNET50_ROWS]

REAL_WORKLOADS: dict[str, list[GEMM]] = {
    "BERT-Large": BERT_LARGE,
    "GPT-J": GPT_J,
    "DLRM": DLRM,
    "ResNet50": RESNET50,
}


def synthetic_dataset(n: int = 1000, seed: int = 0,
                      lo: int = 16, hi: int = 8192) -> list[GEMM]:
    """Paper §V-C synthetic dataset: M, N, K uniform over powers of two in
    [16, 8192] (1000 datapoints)."""
    rng = random.Random(seed)
    choices = []
    v = lo
    while v <= hi:
        choices.append(v)
        v *= 2
    return [GEMM(rng.choice(choices), rng.choice(choices),
                 rng.choice(choices), label=f"synthetic#{i}")
            for i in range(n)]


def square_sweep(lo: int = 64, hi: int = 8192) -> list[GEMM]:
    """Appendix Fig. 13: square GEMMs (X, X, X) from 64 to 8192."""
    out, v = [], lo
    while v <= hi:
        out.append(GEMM(v, v, v, label=f"square{v}"))
        v *= 2
    return out


def all_real_gemms() -> list[GEMM]:
    out: list[GEMM] = []
    for name, gs in REAL_WORKLOADS.items():
        out.extend(gs)
    return out
