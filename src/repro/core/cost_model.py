"""Analytical energy / latency / throughput evaluation (paper §V-D).

Energy = Σ_level (accesses · access cost) + MACs · E_mac + adds · 0.05 pJ.
Cycles = max(compute cycles, SMEM-BW cycles, DRAM-BW cycles)   [pipelined]
TOPS/W = ops / energy[pJ];   GFLOPS = ops / time[ns]   (ops = 2 · MACs).

Calibration choices (DESIGN.md §7, validated in tests/test_calibration.py):
  * Table IV latency is per serial MAC step of a CiM unit: a full-array
    activation takes (active Rh steps)·(active Ch steps)·latency_ns.
    => A-1 saturates at 2·(64·4)/9 ns = 56.9 GFLOPS, D-1 at 2·(256·16)/18 ns
    = 455 GFLOPS — exactly the appendix Fig. 13 saturation values.
  * Primitives at RF share one input driver: array activations serialize
    (matching the 455 GFLOPS ceiling with 3 arrays).  SMEM banks have
    independent ports: arrays run in parallel (configB ≈ 10× RF, Fig. 11b).
  * DRAM weight streaming for CiM tiles is strided: 50 % effective
    bandwidth (reproduces the ~31 GFLOPS M=1 decode/DLRM cells).
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from .gemm import GEMM
from .loopnest import Loop, ceil_div, coverage_factor, revisit_factor
from .mapping import PSUM_BYTES, CiMMapping, candidate_mappings
from .memory import (DRAM, RF, SMEM, TEMPORAL_REDUCTION_PJ, CiMSystemConfig,
                     MemoryLevel)
from .primitives import precision_factors

DRAM_STREAM_EFFICIENCY = 0.5   # strided CiM weight/input tiles (DESIGN.md §7)


@dataclasses.dataclass(frozen=True)
class Metrics:
    """System-level evaluation result for one GEMM + mapping."""

    ops: float
    energy_pj: float
    time_ns: float
    compute_ns: float
    dram_ns: float
    smem_ns: float
    utilization: float
    dram_bytes: float
    smem_bytes: float
    energy_breakdown_pj: dict
    mapping: object = None

    @property
    def tops_per_w(self) -> float:
        return self.ops / self.energy_pj if self.energy_pj else 0.0

    @property
    def gflops(self) -> float:
        return self.ops / self.time_ns if self.time_ns else 0.0

    @property
    def fj_per_op(self) -> float:
        return 1e3 * self.energy_pj / self.ops

    @property
    def edp(self) -> float:
        return self.energy_pj * self.time_ns

    def row(self) -> dict:
        return {
            "tops_per_w": self.tops_per_w, "gflops": self.gflops,
            "utilization": self.utilization, "energy_pj": self.energy_pj,
            "time_ns": self.time_ns, "dram_bytes": self.dram_bytes,
        }


def metrics_from_row(ops: float, row: dict, mapping=None) -> Metrics:
    """Build a Metrics from one row of a batched evaluation result
    (vectorized.evaluate_flat / evaluate_baseline_flat outputs).

    The batched path computes aggregate energy only, so the per-level
    breakdown dict is empty; everything the planner consumes (energy,
    time, throughput, utilization, traffic) is populated.
    """
    return Metrics(
        ops=float(ops),
        energy_pj=float(row["energy_pj"]),
        time_ns=float(row["time_ns"]),
        compute_ns=float(row.get("compute_ns", 0.0)),
        dram_ns=float(row.get("dram_ns", 0.0)),
        smem_ns=float(row.get("smem_ns", 0.0)),
        utilization=float(row.get("utilization", 0.0)),
        dram_bytes=float(row.get("dram_bytes", 0.0)),
        smem_bytes=float(row.get("smem_bytes", 0.0)),
        energy_breakdown_pj={},
        mapping=mapping,
    )


def _dram_order_candidates(mapping: CiMMapping, order_mode: str):
    loops = mapping.dram_loops
    if order_mode == "greedy":
        return [loops]
    return [tuple(p) for p in itertools.permutations(loops)]


def evaluate_cim(mapping: CiMMapping, order_mode: str = "exact",
                 dram_eff: float = DRAM_STREAM_EFFICIENCY) -> Metrics:
    """Evaluate one CiM mapping; chooses the best DRAM loop order."""
    best: Metrics | None = None
    for order in _dram_order_candidates(mapping, order_mode):
        m = _evaluate_cim_order(mapping, order, dram_eff)
        if best is None or m.energy_pj < best.energy_pj:
            best = m
    return best


def _evaluate_cim_order(mp: CiMMapping, dram_loops: tuple[Loop, ...],
                        dram_eff: float) -> Metrics:
    g, cfg, p = mp.gemm, mp.cfg, mp.cfg.prim
    at_rf = cfg.cim_level == "RF"

    k0, n0 = min(g.K, mp.k0), min(g.N, mp.n0)
    k_tiles, n_tiles = mp.k_tiles, mp.n_tiles
    waves = g.M * k_tiles * n_tiles            # array-activation groups

    # ---- compute time ------------------------------------------------------
    # per-precision macro scaling vs the Table-IV INT8 calibration point
    # (identity at INT8): energy_x on the MAC energy, latency_x on the
    # activation latency, colpar_x on the usable column parallelism
    energy_x, latency_x, colpar_x = precision_factors(
        p.compute_type, g.bits, g.fp)
    row_steps = ceil_div(mp.k_arr, p.Rp)       # serial row groups (<= Rh)
    col_steps = math.ceil(mp.n_arr / (p.Cp * colpar_x))  # serial col groups
    steps_per_activation = row_steps * col_steps
    serial_arrays = mp.n_arrays if (cfg.serialize_primitives and at_rf) else 1
    compute_ns = (waves * steps_per_activation * serial_arrays
                  * p.latency_ns * latency_x)

    # ---- traffic -----------------------------------------------------------
    # Loops above the buffer residency (innermost-first): DRAM-level loops.
    # Loops above the CiM weight residency: buffer-level growth loops
    # (K inner of N — paper's M<K<N compute order), then DRAM loops.
    above_buffer = list(dram_loops)
    above_weights = [("K", mp.fk), ("N", mp.fn)] + above_buffer

    e = {}
    dram_bytes = 0.0
    smem_bytes = 0.0

    # Weights: DRAM -> CiM arrays (footprint = one buffer residency's worth
    # of stationary tiles: (k0*fk) x (n0*fn)).
    w_fills = (min(g.K, mp.k0 * mp.fk) * min(g.N, mp.n0 * mp.fn)
               ) * revisit_factor(above_buffer, "W")
    # cap: never less than one full pass of the weight matrix
    w_fills = max(w_fills, g.weight_elems)
    e["dram_W"] = DRAM.energy_pj(w_fills)
    dram_bytes += w_fills
    # writing weights into the arrays (charged at the hosting level's port)
    host = RF if at_rf else SMEM
    e["cim_write_W"] = host.energy_pj(w_fills)

    if at_rf:
        # Input tile (m1 x k0*fk) and psum tile (m1 x n0*fn) live in SMEM.
        a_tile = mp.m1 * min(g.K, mp.k0 * mp.fk)
        a_fills = a_tile * revisit_factor(above_buffer, "A")
        a_fills = max(a_fills, g.input_elems)
        e["dram_A"] = DRAM.energy_pj(a_fills)
        dram_bytes += a_fills

        z_tile = mp.m1 * min(g.N, mp.n0 * mp.fn)
        r = revisit_factor(above_buffer, "Z")
        cov = coverage_factor(above_buffer, "Z")
        spills = z_tile * max(0, r - cov)          # psum spill round-trips
        z_dram = z_tile * cov + 2 * spills * PSUM_BYTES  # final INT8 + RMW
        e["dram_Z"] = DRAM.energy_pj(max(z_dram, g.output_elems))
        dram_bytes += max(z_dram, g.output_elems)

        # SMEM port: input-driver reads (k0 per activation group, broadcast
        # across columns) and psum read-modify-write (n0 per group, 4 B).
        a_reads = waves * k0
        z_rmw = 2.0 * waves * n0 * PSUM_BYTES
        e["smem_A"] = SMEM.energy_pj(a_reads)
        e["smem_Z"] = SMEM.energy_pj(z_rmw)
        smem_bytes += a_reads + z_rmw
    else:
        # CiM at SMEM: inputs stream straight from DRAM; partial sums spill
        # to DRAM whenever K does not fully reduce in-array.
        a_fills = waves * k0
        e["dram_A"] = DRAM.energy_pj(a_fills)
        dram_bytes += a_fills
        spills = g.output_elems * max(0, k_tiles - 1)
        z_dram = g.output_elems + 2 * spills * PSUM_BYTES
        e["dram_Z"] = DRAM.energy_pj(z_dram)
        dram_bytes += z_dram

    # ---- compute energy ----------------------------------------------------
    macs = g.macs
    e["mac"] = macs * p.mac_energy_pj * energy_x
    # temporal reductions: one add per output element per K-tile beyond the
    # in-array reduction (plus serial row groups within an activation).
    adds = g.output_elems * max(0, k_tiles * row_steps - 1)
    e["reduction"] = adds * TEMPORAL_REDUCTION_PJ

    energy = sum(e.values())

    # ---- bandwidth-limited time (fully pipelined: take the max) ------------
    dram_ns = dram_bytes / (DRAM.bandwidth_bytes_per_cycle * dram_eff)
    smem_ns = (smem_bytes / SMEM.bandwidth_bytes_per_cycle
               if math.isfinite(SMEM.bandwidth_bytes_per_cycle) else 0.0)
    time_ns = max(compute_ns, dram_ns, smem_ns)

    return Metrics(ops=g.ops, energy_pj=energy, time_ns=time_ns,
                   compute_ns=compute_ns, dram_ns=dram_ns, smem_ns=smem_ns,
                   utilization=mp.utilization, dram_bytes=dram_bytes,
                   smem_bytes=smem_bytes, energy_breakdown_pj=e, mapping=mp)


def evaluate(gemm: GEMM, cfg: CiMSystemConfig,
             order_mode: str = "exact") -> Metrics:
    """Map (paper algorithm) + evaluate one GEMM on a CiM system.

    Scores every candidate buffer residency the priority mapper emits and
    returns the access-minimal one (the paper's greedy objective)."""
    best: Metrics | None = None
    for mp in candidate_mappings(gemm, cfg, order_mode):
        m = evaluate_cim(mp, order_mode)
        if best is None or m.energy_pj < best.energy_pj:
            best = m
    return best
