"""Memory hierarchy + energy model (paper §V-A, Table III).

Baseline SM hierarchy: DRAM -> SMEM (256 KB, 42 B/cyc... paper gives SMEM
42 B/cycle and DRAM 32 B/cycle) -> RF (4×4 KB) -> PE buffers.

Energy costs (INT8, 45 nm, Table III) are per *access*; the paper does not
state the access width.  We expose `access_granularity_bytes` per level and
calibrate it so system-level TOPS/W reproduces the paper's reported numbers
(see DESIGN.md §7 and tests/test_calibration.py).
"""
from __future__ import annotations

import dataclasses
import math

from .primitives import CiMPrimitive

TEMPORAL_REDUCTION_PJ = 0.05   # pJ per partial-sum addition (paper §V-D)


@dataclasses.dataclass(frozen=True)
class MemoryLevel:
    name: str
    capacity_bytes: float                 # math.inf for DRAM
    access_energy_pj: float               # per access (Table III)
    access_granularity_bytes: int         # bytes per access (calibrated)
    bandwidth_bytes_per_cycle: float      # math.inf if never the bottleneck

    def energy_pj_per_byte(self) -> float:
        return self.access_energy_pj / self.access_granularity_bytes

    def energy_pj(self, n_bytes: float) -> float:
        """Energy for moving n_bytes through this level's port."""
        accesses = math.ceil(n_bytes / self.access_granularity_bytes)
        return accesses * self.access_energy_pj


# --- Table III / §V-A constants -------------------------------------------
# Granularities are the calibration knob (DESIGN.md §7): DRAM 512 pJ per 8 B
# burst (64 pJ/B) reproduces the paper's 0.03 TOPS/W M=1 cells and the
# ~1.75 TOPS/W large-K plateau; SMEM is a 32 B bank access; RF an 8 B
# operand-collector read.

DRAM = MemoryLevel("DRAM", math.inf, 512.00, 8, 32.0)
SMEM = MemoryLevel("SMEM", 256 * 1024, 124.69, 32, 42.0)
RF = MemoryLevel("RF", 4 * 4 * 1024, 11.47, 16, math.inf)

LEVELS: dict[str, MemoryLevel] = {"DRAM": DRAM, "SMEM": SMEM, "RF": RF}


def iso_area_primitive_count(level: MemoryLevel, prim: CiMPrimitive) -> int:
    """How many CiM primitives fit in a level under iso-area (paper §VI).

    round(level capacity / (primitive capacity × area overhead)); RF with
    Digital-6T gives the paper's 3.  For SMEM "configB" the paper scales the
    RF count by the capacity ratio (16×); see `configb_count`.
    """
    n = round(level.capacity_bytes / (prim.capacity_bytes * prim.area_overhead))
    return max(1, int(n))


def configb_count(prim: CiMPrimitive) -> int:
    """Paper Fig. 11 configB: 16× the RF iso-area count (capacity ratio)."""
    return 16 * iso_area_primitive_count(RF, prim)


@dataclasses.dataclass(frozen=True)
class CiMSystemConfig:
    """Where CiM is integrated and how many primitives it gets.

    cim_level: "RF" or "SMEM".  When CiM sits at RF, inputs stream from SMEM
    and SMEM still buffers input/output tiles (paper Fig. 6/11a).  When CiM
    sits at SMEM, there is no intermediate buffer level: inputs/outputs move
    directly between DRAM and the CiM arrays (paper §VI-C).
    """

    prim: CiMPrimitive
    cim_level: str = "RF"
    n_prims: int | None = None          # default: iso-area count
    serialize_primitives: bool = True   # DESIGN.md §7 calibration
    kn_balance_threshold: int = 4       # paper §IV-B multi-primitive rule

    def resolved_n_prims(self) -> int:
        if self.n_prims is not None:
            return self.n_prims
        return iso_area_primitive_count(LEVELS[self.cim_level], self.prim)
