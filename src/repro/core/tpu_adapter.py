"""WWW mapping algorithm re-targeted at the TPU memory hierarchy.

The paper chooses how much weight to hold stationary in a CiM array given
its capacity and Rp/Cp/Rh/Ch geometry.  On TPU the analogous decision is
the Pallas BlockSpec: how large a (bk x bn) INT8 weight tile to hold
resident in VMEM while activations stream through the MXU.

Mapping of concepts (DESIGN.md §3):
  CiM array capacity    -> VMEM weight-tile budget
  Rp (parallel rows)    -> MXU contraction extent (128 sublanes)
  Cp (parallel cols)    -> MXU lane extent (128)
  Rh x Ch serial MACs   -> grid steps per resident tile
  SMEM A/Z buffering    -> VMEM activation + accumulator blocks
  "K within reduction"  -> psums must stay in VMEM scratch (never HBM)

`choose_blocks` runs the same priority logic as core.mapping: maximize the
stationary weight tile (priority 1/2), then size the M stream so the
activation + accumulator blocks fit the remaining VMEM (priority 3 /
Algorithm 1).
"""
from __future__ import annotations

from .loopnest import ceil_div

MXU = 128                       # MXU systolic extent
VMEM_BUDGET = 8 * 1024 * 1024   # bytes we allow a kernel instance to claim
PSUM_BYTES = 4                  # f32 accumulator


def _round_down_mult(x: int, m: int) -> int:
    return max(m, (x // m) * m)


def choose_blocks(M: int, N: int, K: int, vmem: int = VMEM_BUDGET,
                  act_bytes: int = 2, w_bytes: int = 1
                  ) -> tuple[int, int, int]:
    """Pick (block_m, block_n, block_k) for the int8 GEMM kernel.

    Priority 1 (weight-stationary): grow the (bk x bn) weight tile toward
    half the VMEM budget, MXU-aligned, K first (the paper maps K to rows
    and prioritizes in-array reduction depth).
    Priority 3 (Algorithm 1): the M block then takes what fits alongside
    the activation (bm x bk) and accumulator (bm x bn) blocks.
    """
    w_budget = vmem // 2
    bk = min(_round_down_mult(K, MXU) if K >= MXU else K, 2048)
    bn = min(_round_down_mult(N, MXU) if N >= MXU else N, 1024)
    # shrink until the weight tile fits its budget (K last — reduction depth
    # is the paper's priority)
    while bk * bn * w_bytes > w_budget and bn > MXU:
        bn //= 2
    while bk * bn * w_bytes > w_budget and bk > MXU:
        bk //= 2

    rem = vmem - bk * bn * w_bytes
    # bm x (bk act + bn psum) must fit the remainder
    per_row = bk * act_bytes + bn * PSUM_BYTES
    bm = max(8, min(512, rem // per_row))
    bm = min(bm, M)
    # legalize: divisibility with the true dims
    bm = _largest_divisor_leq(M, bm)
    bn = _largest_divisor_leq(N, bn)
    bk = _largest_divisor_leq(K, bk)
    return bm, bn, bk


def _largest_divisor_leq(x: int, cap: int) -> int:
    cap = max(1, min(x, cap))
    for d in range(cap, 0, -1):
        if x % d == 0:
            return d
    return 1


def grid_steps(M: int, N: int, K: int, blocks: tuple[int, int, int]) -> int:
    bm, bn, bk = blocks
    return ceil_div(M, bm) * ceil_div(N, bn) * ceil_div(K, bk)
