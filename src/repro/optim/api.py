"""Optimizer factory: (init_fn, update_fn) pairs keyed by RunConfig."""
from __future__ import annotations

from .adafactor import adafactor_init, adafactor_update
from .adamw import adamw_init, adamw_update


def make_optimizer(name: str, weight_decay: float = 0.1):
    if name == "adamw":
        def update(p, g, s, lr):
            return adamw_update(p, g, s, lr, weight_decay=weight_decay)
        return adamw_init, update
    if name == "adafactor":
        def update(p, g, s, lr):
            return adafactor_update(p, g, s, lr,
                                    weight_decay=weight_decay * 0.0)
        return adafactor_init, update
    raise ValueError(f"unknown optimizer {name}")
