"""Adafactor (factored second moment) — O(n+m) optimizer state for the
very large assigned archs (jamba-398B, llama-3.2-vision-90B), where full
Adam moments would not fit HBM at the production mesh size."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor_init(params):
    def st(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(st, params,
                              is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, lr, *, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-decay)

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if _factored(p):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1, keepdims=True),
                                   eps)[..., None])
            update = g32 * jax.lax.rsqrt(denom + eps)
            nv = {"vr": vr, "vc": vc}
        else:
            nvv = beta2 * v["v"] + (1 - beta2) * g2
            update = g32 * jax.lax.rsqrt(nvv + eps)
            nv = {"v": nvv}
        # update clipping (RMS <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(jnp.square(update)) + eps)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        if weight_decay:
            update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, {"v": new_v, "step": step}
