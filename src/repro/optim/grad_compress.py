"""INT8 gradient compression with error feedback (distributed-optimization
trick for the cross-pod data-parallel all-reduce).

Each worker quantizes its local gradient to int8 with a per-tensor scale,
all-reduces the quantized values (8x fewer bytes over the slow pod
interconnect), dequantizes, and carries the quantization residual into the
next step (error feedback keeps the compression unbiased over time).

Used inside shard_map over the ('pod',) axis — the intra-pod reduction
stays full-precision (fast ICI), only the pod-level reduce is compressed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads, errors, axis_name: str):
    """All-reduce `grads` over `axis_name` in int8 with error feedback.

    Returns (reduced_grads_fp32_mean, new_errors).  Must run inside
    shard_map/vmap with `axis_name` bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quant(g32)
        # sum int8 payloads in int32; scales are tiny, psum them too
        qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # each worker may have a different scale; communicate the max and
        # requantize against it so the sum is consistent
        smax = jax.lax.pmax(scale, axis_name)
        q2 = jnp.clip(jnp.round(g32 / smax), -127, 127).astype(jnp.int32)
        qsum = jax.lax.psum(q2, axis_name)
        reduced = qsum.astype(jnp.float32) * smax / n
        new_e = g32 - q2.astype(jnp.float32) * smax
        return reduced, new_e

    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in out]),
            td.unflatten([o[1] for o in out]))
