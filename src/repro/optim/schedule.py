"""Learning-rate schedules (jnp-traceable, usable inside jitted steps).

`linear_warmup_cosine` is the production default: linear ramp over
`warmup` steps, cosine decay to `min_frac * base_lr` by `total`."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup_cosine(step, base_lr: float, warmup: int,
                         total: int, min_frac: float = 0.1):
    t = jnp.asarray(step, jnp.float32)
    warm = base_lr * jnp.minimum(1.0, t / jnp.maximum(1.0, float(warmup)))
    prog = jnp.clip((t - warmup) / jnp.maximum(1.0, float(total - warmup)),
                    0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac)
                     * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
