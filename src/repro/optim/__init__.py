"""Optimizers (pure JAX, no optax dependency)."""
from .adamw import adamw_init, adamw_update
from .adafactor import adafactor_init, adafactor_update
from .api import make_optimizer
from .schedule import linear_warmup_cosine

__all__ = ["adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "make_optimizer", "linear_warmup_cosine"]
