"""INT8 quantization (paper's evaluation precision) + planner-gated linear
+ the jit-static KernelPlanTable routing verdicts into the model stack."""
from .int8 import (PROJECTION_WEIGHT_NAMES, dequantize_weight,
                   planned_linear, quantization_error, quantize_model_params,
                   quantize_tree, quantize_weight)
from .plan_table import KernelPlanTable, PlanEntry, strip_model_prefix

__all__ = ["quantize_weight", "dequantize_weight", "quantize_tree",
           "quantize_model_params", "planned_linear", "quantization_error",
           "PROJECTION_WEIGHT_NAMES", "KernelPlanTable", "PlanEntry",
           "strip_model_prefix"]
