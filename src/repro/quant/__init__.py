"""Quantized weight formats (INT8 / packed INT4 / scaled FP8), the
planner-gated linear routes, and the jit-static KernelPlanTable carrying
What/When/Where verdicts into the model stack."""
from .int8 import (PROJECTION_WEIGHT_NAMES, dequantize_weight,
                   planned_linear, quantization_error, quantize_model_params,
                   quantize_tree, quantize_weight)
from .lowbit import (dequant_contract_fp8, dequant_contract_int4,
                     dequantize_weight_fp8, dequantize_weight_int4,
                     pack_int4, quantize_model_params_lowbit,
                     quantize_weight_fp8, quantize_weight_int4, unpack_int4,
                     weight_format)
from .plan_table import KernelPlanTable, PlanEntry, strip_model_prefix

__all__ = ["quantize_weight", "dequantize_weight", "quantize_tree",
           "quantize_model_params", "planned_linear", "quantization_error",
           "PROJECTION_WEIGHT_NAMES", "KernelPlanTable", "PlanEntry",
           "strip_model_prefix",
           "quantize_weight_int4", "dequantize_weight_int4", "pack_int4",
           "unpack_int4", "quantize_weight_fp8", "dequantize_weight_fp8",
           "dequant_contract_int4", "dequant_contract_fp8",
           "quantize_model_params_lowbit", "weight_format"]
