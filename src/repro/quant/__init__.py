"""INT8 quantization (paper's evaluation precision) + planner-gated linear."""
from .int8 import (dequantize_weight, planned_linear, quantization_error,
                   quantize_tree, quantize_weight)

__all__ = ["quantize_weight", "dequantize_weight", "quantize_tree",
           "planned_linear", "quantization_error"]
