"""KernelPlanTable — the What/When/Where verdicts as a jit-static routing
table.

`ServeSession.kernel_plan` produces one planner `Decision` per decode GEMM;
this module freezes those verdicts into a hashable structure the model
stack can close a `jax.jit` over.  Because the table is Python-static, the
gate is resolved at trace time: a gated label lowers to the weight-
stationary INT8 Pallas kernel, an ungated one to the plain XLA matmul, and
the compiled decode executable never branches (one lowered program, no
per-token retrace).

Labels are the *short* projection names ("Wq", "mlp-down", "ssm-BCdt",
"lm_head", ...) — the `gemms_of_model` labels with the model-name prefix
stripped — so the table is independent of which config produced it.
Lookup of a label the planner never saw raises `KeyError` (listing the
known labels): model-side label drift must not silently disable gating.

Tables are **versioned** by content: `digest` is a stable hash of the
sorted entries (two tables built from the same decisions in any order
share it), which is what the adaptive serving layer keys its bounded
executable cache on and what telemetry reports as the plan version.
`flips(other)` diffs two versions by their "when" gate, and
`with_flip(label)` is the forced-flip harness used by the adaptive
tests/bench.
"""
from __future__ import annotations

import dataclasses
import hashlib
from functools import cached_property
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One label's verdict: the paper's when (use_cim), what and where."""
    use_cim: bool
    what: str = "baseline"
    where: str = "PE"


def strip_model_prefix(label: str, model_name: str = "") -> str:
    prefix = f"{model_name} "
    return label[len(prefix):] if model_name and label.startswith(prefix) \
        else label


@dataclasses.dataclass(frozen=True)
class KernelPlanTable:
    """Hashable label -> PlanEntry map (valid as a jit-static closure)."""
    entries: tuple[tuple[str, PlanEntry], ...] = ()

    @classmethod
    def from_decisions(cls, decisions: Iterable, model_name: str = ""
                       ) -> "KernelPlanTable":
        """Build from planner Decisions (e.g. ServeSession.kernel_plan
        values); `model_name` strips the `gemms_of_model` label prefix."""
        rows = []
        for d in decisions:
            lab = strip_model_prefix(d.gemm.label, model_name)
            rows.append((lab, PlanEntry(use_cim=bool(d.use_cim),
                                        what=d.what, where=d.where)))
        return cls(entries=tuple(sorted(rows)))

    @cached_property
    def _index(self) -> dict:
        return dict(self.entries)

    @property
    def labels(self) -> tuple:
        return tuple(lab for lab, _ in self.entries)

    def entry(self, label: str) -> PlanEntry:
        try:
            return self._index[label]
        except KeyError:
            raise KeyError(
                f"unknown GEMM label {label!r}: not in the kernel plan "
                f"(known labels: {sorted(self._index)})") from None

    def use_cim(self, label: str) -> bool:
        """The planner's "when" gate for one projection label.  Raises
        KeyError on labels absent from the plan — a renamed model-side
        projection must fail loudly, not silently run ungated."""
        return self.entry(label).use_cim

    def ungated(self) -> "KernelPlanTable":
        """Copy with every gate forced off (the parity-baseline program:
        identical labels and quantized weights, all-standard routing)."""
        return KernelPlanTable(entries=tuple(
            (lab, dataclasses.replace(e, use_cim=False))
            for lab, e in self.entries))

    # --- versioning -------------------------------------------------------

    @cached_property
    def digest(self) -> str:
        """Stable content hash — the table's *version*.  Entries are kept
        sorted by `from_decisions`, so two tables built from the same
        decisions in any order share one digest; any verdict change
        yields a new one.  (Python's built-in hash() is salted per
        process; this digest is reproducible across runs, so it can live
        in benchmark artifacts and serve telemetry.)"""
        return hashlib.sha256(repr(self.entries).encode()).hexdigest()[:12]

    def flips(self, other: "KernelPlanTable") -> tuple[str, ...]:
        """Labels whose "when" gate (use_cim) differs between the two
        versions; a label present in only one table counts as flipped."""
        labels = set(self._index) | set(other._index)
        out = []
        for lab in sorted(labels):
            a, b = self._index.get(lab), other._index.get(lab)
            if a is None or b is None or a.use_cim != b.use_cim:
                out.append(lab)
        return tuple(out)

    def with_flip(self, label: str) -> "KernelPlanTable":
        """Copy with one label's gate toggled — the deterministic
        forced-flip harness for the adaptive-serving tests and bench.
        Raises the KeyError-with-known-labels contract on unknown
        labels."""
        self.entry(label)          # enforce the drift gate
        return KernelPlanTable(entries=tuple(
            (lab, dataclasses.replace(e, use_cim=not e.use_cim)
             if lab == label else e)
            for lab, e in self.entries))
