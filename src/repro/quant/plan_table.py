"""KernelPlanTable — the What/When/Where verdicts as a jit-static routing
table.

`ServeSession.kernel_plan` produces one planner `Decision` per decode GEMM;
this module freezes those verdicts into a hashable structure the model
stack can close a `jax.jit` over.  Because the table is Python-static, the
gate is resolved at trace time: a gated label lowers to the weight-
stationary INT8 Pallas kernel, an ungated one to the plain XLA matmul, and
the compiled decode executable never branches (one lowered program, no
per-token retrace).

Labels are the *short* projection names ("Wq", "mlp-down", "ssm-BCdt",
"lm_head", ...) — the `gemms_of_model` labels with the model-name prefix
stripped — so the table is independent of which config produced it.
Lookup of a label the planner never saw raises `KeyError` (listing the
known labels): model-side label drift must not silently disable gating.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One label's verdict: the paper's when (use_cim), what and where."""
    use_cim: bool
    what: str = "baseline"
    where: str = "PE"


def strip_model_prefix(label: str, model_name: str = "") -> str:
    prefix = f"{model_name} "
    return label[len(prefix):] if model_name and label.startswith(prefix) \
        else label


@dataclasses.dataclass(frozen=True)
class KernelPlanTable:
    """Hashable label -> PlanEntry map (valid as a jit-static closure)."""
    entries: tuple[tuple[str, PlanEntry], ...] = ()

    @classmethod
    def from_decisions(cls, decisions: Iterable, model_name: str = ""
                       ) -> "KernelPlanTable":
        """Build from planner Decisions (e.g. ServeSession.kernel_plan
        values); `model_name` strips the `gemms_of_model` label prefix."""
        rows = []
        for d in decisions:
            lab = strip_model_prefix(d.gemm.label, model_name)
            rows.append((lab, PlanEntry(use_cim=bool(d.use_cim),
                                        what=d.what, where=d.where)))
        return cls(entries=tuple(sorted(rows)))

    @cached_property
    def _index(self) -> dict:
        return dict(self.entries)

    @property
    def labels(self) -> tuple:
        return tuple(lab for lab, _ in self.entries)

    def entry(self, label: str) -> PlanEntry:
        try:
            return self._index[label]
        except KeyError:
            raise KeyError(
                f"unknown GEMM label {label!r}: not in the kernel plan "
                f"(known labels: {sorted(self._index)})") from None

    def use_cim(self, label: str) -> bool:
        """The planner's "when" gate for one projection label.  Raises
        KeyError on labels absent from the plan — a renamed model-side
        projection must fail loudly, not silently run ungated."""
        return self.entry(label).use_cim

    def ungated(self) -> "KernelPlanTable":
        """Copy with every gate forced off (the parity-baseline program:
        identical labels and quantized weights, all-standard routing)."""
        return KernelPlanTable(entries=tuple(
            (lab, dataclasses.replace(e, use_cim=False))
            for lab, e in self.entries))
