"""INT8 post-training quantization (the paper's fixed evaluation precision)
+ the CiM-planner-gated quantized linear layer.

`quantize_params` converts the weight matrices of a model to int8 with
per-output-channel scales; `planned_linear` consults the WWW planner
decision to route large-M GEMMs through the weight-stationary Pallas
kernel and keep small-M (decode) GEMMs on the standard path — the paper's
"when to CiM" answer, enforced at runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_weight(w):
    """(K, N) -> (int8 (K, N), f32 (N,)) per-output-channel symmetric."""
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_weight(q, scale, dtype=jnp.float32):
    """Per-output-channel dequant in `dtype` (the canonical expression —
    the numerical *reference* every fused contraction is tested against).
    Supports stacked leading axes: q (..., K, N) with scale (..., N).

    The serving hot path no longer calls this per step: `dequant_contract`
    contracts against the raw int8 weight and applies the scale as an
    O(batch·d_out) epilogue instead of materializing this O(K·N) array."""
    return q.astype(dtype) * scale.astype(dtype)[..., None, :]


def _epilogue_scale(spec: str, scale):
    """Reshape/transpose a per-output-channel `scale` so it broadcasts
    against the *output* of `einsum(spec, x, q)`.

    The weight operand's second-to-last letter is the contracted input
    channel (the repo-wide (..., K, N) weight convention); every other
    weight letter carries a scale axis.  Returns None when a scale axis
    does not survive into the output (caller falls back to materializing
    the dequantized weight — no such spec exists in-repo today)."""
    ins, out = spec.replace(" ", "").split("->")
    w_spec = ins.split(",")[1]
    k = w_spec[-2]
    s_letters = [c for c in w_spec if c != k]      # scale axis order
    if any(c not in out for c in s_letters):
        return None
    s = jnp.transpose(scale, [s_letters.index(c)
                              for c in out if c in s_letters])
    dims = iter(s.shape)
    return s.reshape([next(dims) if c in s_letters else 1 for c in out])


def dequant_contract(x, q, scale, spec: str | None = None, *,
                     materialize: bool = False):
    """x · dequant(q, scale) with the per-output-channel scale fused into
    the matmul *epilogue*: contract against the raw int8 weight (cast to
    x.dtype — exact for int8 values) and scale the O(batch·d_out) output,
    instead of materializing the O(K·N) dequantized weight every call.
    Mathematically identical to the canonical expression up to float
    reassociation: sum_k x_k·(q_kj·s_j) == (sum_k x_k·q_kj)·s_j.

    `materialize=True` keeps the canonical `dequantize_weight` expression
    — the parity reference the fused path is tested against."""
    if not materialize:
        qx = q.astype(x.dtype)
        if spec is None:
            s = scale.astype(x.dtype)
            return (x @ qx) * (s if q.ndim == 2 else s[..., None, :])
        s = _epilogue_scale(spec, scale)
        if s is not None:
            return jnp.einsum(spec, x, qx) * s.astype(x.dtype)
    w = dequantize_weight(q, scale, x.dtype)
    return jnp.einsum(spec, x, w) if spec else x @ w


def quantize_tree(params, min_size: int = 1 << 16):
    """Quantize every >=2D weight leaf above `min_size` elements.

    Returns a tree of {"q": int8, "scale": f32} replacing those leaves."""
    def q(p):
        if hasattr(p, "ndim") and p.ndim == 2 and p.size >= min_size:
            qw, s = quantize_weight(p)
            return {"q": qw, "scale": s}
        return p
    return jax.tree.map(q, params)


def planned_linear(x, w_q, w_scale, use_cim_path: bool,
                   interpret: bool | None = None):
    """y = x @ dequant(w) — routed per the planner decision.

    use_cim_path=True  -> weight-stationary INT8 Pallas kernel
    use_cim_path=False -> plain XLA matmul on the dequantized weights
    (the paper: never deploy CiM for M=1 / low-reuse GEMMs).

    Both branches respect x.dtype: bfloat16 decode activations contract
    against the int8 weight in bfloat16 (no float32 weight
    materialization) and return bfloat16; the Pallas kernel accumulates
    in f32 internally and casts its output back.  The XLA branch fuses
    the per-output-channel scale into the matmul epilogue
    (`dequant_contract`) rather than dequantizing the full weight.
    """
    if use_cim_path:
        from ..kernels import ops
        b_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = ops.int8_matmul(x2, w_q, w_scale, interpret=interpret)
        return y.reshape(*b_shape, w_q.shape[1]).astype(x.dtype)
    return dequant_contract(x, w_q, w_scale)


# weight-leaf names the runtime gate can quantize: every projection that
# `core.llm_workloads.gemms_of_model` emits a label for.  Norm scales,
# biases, convs, router (kept f32 for routing stability) and the embedding
# gather stay in float.
PROJECTION_WEIGHT_NAMES = frozenset({
    "wq", "wk", "wv", "wo",                      # attention projections
    "w_gate", "w_up", "w_down",                  # dense MLP / MoE experts
    "w_z", "w_x", "w_B", "w_C", "w_dt",          # mamba in-projections
    "out_proj",                                  # mamba out-projection
    "lm_head",
})


def quantize_model_params(params):
    """INT8-quantize every projection weight of a model param tree.

    Unlike size-threshold `quantize_tree`, this walks by *name*: the leaf
    names in PROJECTION_WEIGHT_NAMES are exactly the weights the planner
    has verdicts for.  Stacked (scanned) leaves keep their leading layer /
    expert axes — quantization vmaps over them, so per-(layer, channel)
    scales survive `unstack_tree` inside the decode scan.  Each quantized
    leaf becomes a {"q": int8, "scale": f32} sub-tree (pytree-transparent:
    scan/unstack slice q and scale together).
    """
    from jax.tree_util import DictKey, tree_map_with_path

    def q(path, leaf):
        name = next((p.key for p in reversed(path)
                     if isinstance(p, DictKey)), None)
        if name not in PROJECTION_WEIGHT_NAMES or getattr(
                leaf, "ndim", 0) < 2:
            return leaf
        fn = quantize_weight
        for _ in range(leaf.ndim - 2):      # (layers, [experts,] K, N)
            fn = jax.vmap(fn)
        qw, scale = fn(leaf)
        return {"q": qw, "scale": scale}

    return tree_map_with_path(q, params)


def quantization_error(w, rtol_target: float = 0.02) -> float:
    q, s = quantize_weight(w)
    back = dequantize_weight(q, s)
    num = jnp.linalg.norm(back - w.astype(jnp.float32))
    den = jnp.linalg.norm(w.astype(jnp.float32)) + 1e-12
    return float(num / den)
