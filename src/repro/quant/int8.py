"""INT8 post-training quantization (the paper's fixed evaluation precision)
+ the CiM-planner-gated quantized linear layer.

`quantize_params` converts the weight matrices of a model to int8 with
per-output-channel scales; `planned_linear` consults the WWW planner
decision to route large-M GEMMs through the weight-stationary Pallas
kernel and keep small-M (decode) GEMMs on the standard path — the paper's
"when to CiM" answer, enforced at runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_weight(w):
    """(K, N) -> (int8 (K, N), f32 (N,)) per-output-channel symmetric."""
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_weight(q, scale):
    return q.astype(jnp.float32) * scale[None, :]


def quantize_tree(params, min_size: int = 1 << 16):
    """Quantize every >=2D weight leaf above `min_size` elements.

    Returns a tree of {"q": int8, "scale": f32} replacing those leaves."""
    def q(p):
        if hasattr(p, "ndim") and p.ndim == 2 and p.size >= min_size:
            qw, s = quantize_weight(p)
            return {"q": qw, "scale": s}
        return p
    return jax.tree.map(q, params)


def planned_linear(x, w_q, w_scale, use_cim_path: bool,
                   interpret: bool | None = None):
    """y = x @ dequant(w) — routed per the planner decision.

    use_cim_path=True  -> weight-stationary INT8 Pallas kernel
    use_cim_path=False -> plain XLA matmul on the dequantized weights
    (the paper: never deploy CiM for M=1 / low-reuse GEMMs).
    """
    if use_cim_path:
        from ..kernels import ops
        b_shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = ops.int8_matmul(x2, w_q, w_scale, interpret=interpret)
        return y.reshape(*b_shape, w_q.shape[1]).astype(x.dtype)
    w = dequantize_weight(w_q, w_scale).astype(x.dtype)
    return x @ w


def quantization_error(w, rtol_target: float = 0.02) -> float:
    q, s = quantize_weight(w)
    back = dequantize_weight(q, s)
    num = jnp.linalg.norm(back - w.astype(jnp.float32))
    den = jnp.linalg.norm(w.astype(jnp.float32)) + 1e-12
    return float(num / den)
