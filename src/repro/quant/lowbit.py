"""Low-bit weight formats for the widened What axis: packed INT4 and
scaled FP8 alongside the paper's INT8 evaluation precision.

Formats (pytree sub-trees; the dict *key* is the jit-static format
discriminator `models.layers.linear` dispatches on):

  {"q":  int8 (K, N),              "scale": f32 (N,)}   INT8 (quant.int8)
  {"q4": int8 (ceil(K/2), N),      "scale": f32 (N,)}   packed INT4
  {"qf8": float8_e4m3fn (K, N),    "scale": f32 (N,)}   scaled FP8

INT4 packs two signed nibbles per int8 byte along K (even K-rows in the
low nibble, odd rows in the high nibble) with a per-output-channel /7
symmetric scale; unpacking recovers the signed nibbles with arithmetic
shifts.  FP8 stores e4m3 elements with a per-output-channel scale that
maps each column's max-abs onto the e4m3 dynamic range.  Both formats
reuse the INT8 epilogue-fused contraction structure: contract against
the raw low-bit weight in x.dtype, scale the O(batch·d_out) output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

FP8_DTYPE = jnp.float8_e4m3fn
FP8_MAX = 448.0          # e4m3 finite max


# --- INT4: pack / unpack ----------------------------------------------------

def quantize_weight_int4(w):
    """(K, N) -> (packed int8 (ceil(K/2), N), f32 (N,)) per-channel /7."""
    w = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w), axis=0) / 7.0 + 1e-12
    q = jnp.clip(jnp.round(w / scale[None, :]), -7, 7).astype(jnp.int8)
    return pack_int4(q), scale.astype(jnp.float32)


def pack_int4(q):
    """Pack int8 values in [-8, 7] two-per-byte along axis -2 (K).

    Handles stacked leading axes: (..., K, N) -> (..., ceil(K/2), N)."""
    k = q.shape[-2]
    if k % 2:
        q = jnp.concatenate([q, jnp.zeros_like(q[..., :1, :])], axis=-2)
    lo = q[..., 0::2, :] & jnp.int8(0x0F)
    hi = jnp.left_shift(q[..., 1::2, :], 4)
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed, k: int):
    """Inverse of pack_int4: (..., ceil(K/2), N) int8 -> (..., K, N) int8.

    Arithmetic shifts sign-extend each nibble (int8 >> is arithmetic)."""
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    hi = jnp.right_shift(packed, 4)
    full = jnp.stack([lo, hi], axis=-2)             # (..., Kp, 2, N)
    full = full.reshape(*packed.shape[:-2], 2 * packed.shape[-2],
                        packed.shape[-1])
    return full[..., :k, :]


def dequantize_weight_int4(packed, scale, k: int, dtype=jnp.float32):
    """Canonical reference expression for the packed-INT4 format."""
    return unpack_int4(packed, k).astype(dtype) * scale.astype(dtype)[None, :]


# --- FP8 --------------------------------------------------------------------

def quantize_weight_fp8(w):
    """(K, N) -> (float8_e4m3fn (K, N), f32 (N,)) per-output-channel.

    Scale maps each column's max-abs onto the e4m3 finite range so small-
    magnitude columns keep mantissa resolution."""
    w = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w), axis=0) / FP8_MAX + 1e-12
    qf = (w / scale[None, :]).astype(FP8_DTYPE)
    return qf, scale.astype(jnp.float32)


def dequantize_weight_fp8(qf, scale, dtype=jnp.float32):
    """Canonical reference expression for the FP8 format."""
    return qf.astype(dtype) * scale.astype(dtype)[None, :]


# --- epilogue-fused contractions (mirror quant.int8.dequant_contract) -------

def dequant_contract_int4(x, packed, scale, spec: str | None = None):
    """x · dequant(int4) with the scale fused into the output epilogue.

    Unpacks the nibbles (O(K·N) int8, transient) and contracts in x.dtype
    — exact for int4 magnitudes in every float dtype in use."""
    q = unpack_int4(packed, x.shape[-1]).astype(x.dtype)
    s = scale.astype(x.dtype)
    if spec is None:
        return (x @ q) * (s if q.ndim == 2 else s[..., None, :])
    from .int8 import _epilogue_scale
    se = _epilogue_scale(spec, scale)
    if se is not None:
        return jnp.einsum(spec, x, q) * se.astype(x.dtype)
    return jnp.einsum(spec, x, q * s[..., None, :])


def dequant_contract_fp8(x, qf, scale, spec: str | None = None):
    """x · dequant(fp8) with the scale fused into the output epilogue."""
    q = qf.astype(x.dtype)
    s = scale.astype(x.dtype)
    if spec is None:
        return (x @ q) * (s if qf.ndim == 2 else s[..., None, :])
    from .int8 import _epilogue_scale
    se = _epilogue_scale(spec, scale)
    if se is not None:
        return jnp.einsum(spec, x, q) * se.astype(x.dtype)
    return jnp.einsum(spec, x, q * s[..., None, :])


# --- Pallas GEMM routes -----------------------------------------------------

def planned_linear_int4(x, packed, scale, interpret: bool | None = None):
    """Weight-stationary Pallas route for packed INT4: unpack to int8
    (values in [-7, 7] are exact int8) and reuse the INT8 kernel with the
    /7 scale — same grid, same epilogue fusion."""
    from ..kernels import ops
    b_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    w_q = unpack_int4(packed, x.shape[-1])
    y = ops.int8_matmul(x2, w_q, scale, interpret=interpret)
    return y.reshape(*b_shape, w_q.shape[1]).astype(x.dtype)


def planned_linear_fp8(x, qf, scale, interpret: bool | None = None):
    """Weight-stationary Pallas route for FP8: the kernel upcasts the
    weight tile to f32 in-register, so the e4m3 operand feeds the same
    weight-stationary grid as int8."""
    from ..kernels import ops
    b_shape = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = ops.int8_matmul(x2, qf, scale, interpret=interpret)
    return y.reshape(*b_shape, qf.shape[1]).astype(x.dtype)


# --- format dispatch --------------------------------------------------------

def weight_format(w) -> str | None:
    """Precision token of a quantized weight sub-tree, else None."""
    if not isinstance(w, dict):
        return None
    if "q4" in w:
        return "int4"
    if "qf8" in w:
        return "fp8"
    if "q" in w:
        return "int8"
    return None


def quantize_model_params_lowbit(params, precision: str = "int8"):
    """Name-walked projection quantization at a chosen precision.

    precision "int8" delegates to quant.int8.quantize_model_params;
    "int4"/"fp8" produce {"q4"|"qf8", "scale"} sub-trees with the same
    stacked-leading-axis vmap treatment (per-(layer, channel) scales
    survive unstack_tree inside the decode scan)."""
    from jax.tree_util import DictKey, tree_map_with_path

    from .int8 import PROJECTION_WEIGHT_NAMES, quantize_model_params
    if precision == "int8":
        return quantize_model_params(params)
    if precision == "int4":
        base, key = quantize_weight_int4, "q4"
    elif precision == "fp8":
        base, key = quantize_weight_fp8, "qf8"
    else:
        raise ValueError(f"unknown precision {precision!r} "
                         "(expected int8/int4/fp8)")

    def q(path, leaf):
        name = next((p.key for p in reversed(path)
                     if isinstance(p, DictKey)), None)
        if name not in PROJECTION_WEIGHT_NAMES or getattr(
                leaf, "ndim", 0) < 2:
            return leaf
        fn = base
        for _ in range(leaf.ndim - 2):      # (layers, [experts,] K, N)
            fn = jax.vmap(fn)
        qw, scale = fn(leaf)
        return {key: qw, "scale": scale}

    return tree_map_with_path(q, params)
