"""Blocked causal flash attention Pallas TPU kernel (online softmax).

Grid (batch*heads, q_blocks, kv_blocks); kv innermost with running
(m, l, acc) in VMEM scratch.  Causality skips fully-masked kv blocks via
block-level masking (the lowered kernel still visits them; masked lanes
contribute exp(-inf)=0).  Supports a sliding window (sub-quadratic local
attention for llama4-scout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_kv: int, n_kv: int, seq_offset: int,
            window: int, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bkv, d)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * (1.0 / (d ** 0.5))

    pos_q = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + seq_offset
    pos_k = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= pos_q >= pos_k
    if window:
        mask &= (pos_q - pos_k) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q: (bh, sq, d); k/v: (bh, sk, d).  Heads pre-folded into batch
    (GQA expansion in the ops.py wrapper).  Returns (bh, sq, d)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    bq, bkv = min(block_q, sq), min(block_kv, sk)
    assert sq % bq == 0 and sk % bkv == 0
    n_kv = sk // bkv
    seq_offset = sk - sq      # queries are the tail of the kv sequence

    return pl.pallas_call(
        functools.partial(_kernel, block_q=bq, block_kv=bkv, n_kv=n_kv,
                          seq_offset=seq_offset, window=window,
                          causal=causal),
        grid=(bh, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
