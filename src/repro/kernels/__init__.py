"""Pallas TPU kernels (VMEM-tiled) + jnp oracles.

int8_gemm       — weight-stationary INT8 GEMM (the paper's CiM insight on TPU)
flash_attention — blocked causal attention (prefill)
decode_attention— flash-decoding over long KV caches (serve)
sweep_eval      — fused planner-sweep row evaluator (the sweep engine's
                  backend="pallas" inner loop)
"""
# NOTE: sweep_eval is exported as the MODULE (its main entry point is
# sweep_eval.sweep_eval) — importing the function here would shadow the
# submodule attribute and break `repro.kernels.sweep_eval.<anything>`.
from . import ops, ref, sweep_eval
from .int8_gemm import int8_gemm
from .flash_attention import flash_attention
from .decode_attention import decode_attention
from .sweep_eval import pallas_status

__all__ = ["ops", "ref", "int8_gemm", "flash_attention",
           "decode_attention", "sweep_eval", "pallas_status"]
