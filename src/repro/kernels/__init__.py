"""Pallas TPU kernels (VMEM-tiled) + jnp oracles.

int8_gemm       — weight-stationary INT8 GEMM (the paper's CiM insight on TPU)
flash_attention — blocked causal attention (prefill)
decode_attention— flash-decoding over long KV caches (serve)
"""
from . import ops, ref
from .int8_gemm import int8_gemm
from .flash_attention import flash_attention
from .decode_attention import decode_attention

__all__ = ["ops", "ref", "int8_gemm", "flash_attention", "decode_attention"]
