"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_gemm_ref(x, w_q, w_scale):
    """x: (M, K) f32/bf16; w_q: (K, N) int8; w_scale: (N,) f32 per-channel.

    y = x @ (w_q * scale) computed in f32."""
    w = w_q.astype(jnp.float32) * w_scale[None, :].astype(jnp.float32)
    return (x.astype(jnp.float32) @ w)


def flash_attention_ref(q, k, v, causal=True, window: int = 0):
    """q/k/v: (b, s, h, d) — matches models.attention.naive_causal."""
    b, sq, nh, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        pos_q = jnp.arange(sq)[:, None] + (sk - sq)
        pos_k = jnp.arange(sk)[None, :]
        mask = pos_q >= pos_k
        if window:
            mask &= (pos_q - pos_k) < window
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, length):
    """q: (b, h, d); caches: (b, S, h, d); length: () valid prefix."""
    b, S, nh, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, None, :] < length
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)
