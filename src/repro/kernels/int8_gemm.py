"""Weight-stationary INT8 GEMM Pallas TPU kernel — the paper's CiM insight
adapted to the TPU memory hierarchy (DESIGN.md §3).

CiM analogue on TPU:
  * the (bk x bn) INT8 weight tile is the "CiM array": resident in VMEM,
    reused across the whole M stream (weight-stationary, K->sublanes,
    N->lanes);
  * the MXU plays the Rp x Cp parallel MAC grid;
  * partial sums accumulate in an f32 VMEM scratch across K steps (the
    paper's in-array K reduction / temporal psum accumulation);
  * block sizes come from the WWW mapping algorithm re-targeted at VMEM
    capacity (core.tpu_adapter.choose_blocks).

Grid: (M/bm, N/bn, K/bk), K innermost so each output tile's psums stay in
VMEM (never spill to HBM — the paper's "K must fit the reduction
capability" takeaway, enforced structurally).

dataflow="ws" flips the grid to (N/bn, K/bk, M/bm): M becomes the
innermost loop exactly as the paper's compute order (M < K < N), holding
each weight tile stationary across the entire M stream at the cost of
psum revisits to HBM — the paper-faithful variant, kept for ablation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel_os(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_k: int):
    """Output-stationary: grid (m, n, k), psums in VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(
        x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...]
                      * s_ref[...].astype(jnp.float32)[None, :]
                      ).astype(o_ref.dtype)


def _kernel_ws(x_ref, w_ref, s_ref, o_ref, *, n_k: int):
    """Weight-stationary (paper order M<K<N): grid (n, k, m); the weight
    tile is revisited-stationary while M streams; psums accumulate in the
    HBM-backed output window (the paper's temporal reduction)."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc = jax.lax.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _scale():
        total = o_ref[...].astype(jnp.float32) + acc
        o_ref[...] = (total * s_ref[...].astype(jnp.float32)[None, :]
                      ).astype(o_ref.dtype)

    @pl.when(k != n_k - 1)
    def _accum():
        o_ref[...] += acc.astype(o_ref.dtype)


def int8_gemm(x, w_q, w_scale, *, block_m: int = 256, block_n: int = 256,
              block_k: int = 512, dataflow: str = "os",
              interpret: bool = False):
    """y = x @ dequant(w_q)  with per-output-channel scales.

    x: (M, K) bf16/f32; w_q: (K, N) int8; w_scale: (N,) f32.
    Scale is applied on the last K step (valid because the scale is
    per-output-channel, constant over K).

    NOTE (ws dataflow): output accumulates across K grid steps in f32.
    """
    M, K = x.shape
    K2, N = w_q.shape
    assert K == K2 and w_scale.shape == (N,)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, \
        f"shapes ({M},{N},{K}) not divisible by blocks ({bm},{bn},{bk})"
    n_k = K // bk

    if dataflow == "os":
        grid = (M // bm, N // bn, n_k)
        return pl.pallas_call(
            functools.partial(_kernel_os, n_k=n_k),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
                pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
                pl.BlockSpec((bn,), lambda m, n, k: (n,)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
            out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(x, w_q, w_scale)

    assert dataflow == "ws", dataflow
    grid = (N // bn, n_k, M // bm)
    return pl.pallas_call(
        functools.partial(_kernel_ws, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda n, k, m: (m, k)),
            pl.BlockSpec((bk, bn), lambda n, k, m: (k, n)),
            pl.BlockSpec((bn,), lambda n, k, m: (n,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda n, k, m: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, w_q, w_scale)
