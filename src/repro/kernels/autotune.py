"""Block-size autotune table for the Pallas kernels.

`choose_blocks` (core.tpu_adapter) derives block sizes analytically from
the WWW mapping priorities.  This module layers a small *pinned* table of
block configurations for the GEMM shape classes the serving stack
actually hits — decode GEMVs/micro-batches, skinny down-projections,
prefill-scale GEMMs — because the analytic choice optimizes the weight
tile in isolation while the measured winners also balance grid-step
count (interpret-mode cost on CPU, DMA/compute overlap on TPU).

Every table entry is a *cap*, not a demand: it is legalized down to
divisors of the true dims (the Pallas BlockSpec divisibility contract)
and the whole configuration is checked against the VMEM budget before
use.  A shape no entry matches — or whose pinned entry would bust the
budget — falls back to the analytic `choose_blocks`, so the table can
only ever replace a config with another *valid* one.

`sweep_block_rows` plays the same role for the fused sweep kernel
(kernels.sweep_eval): rows-per-grid-step from a power-of-two ladder,
preferring a single grid step for planner-sized batches while keeping
the per-step field matrices inside the VMEM budget for campaign-scale
batches.
"""
from __future__ import annotations

from ..core.tpu_adapter import (PSUM_BYTES, VMEM_BUDGET,
                                _largest_divisor_leq, choose_blocks)

# (name, predicate(M, N, K), (block_m, block_n, block_k)) — first match
# wins; values are caps, legalized + VMEM-checked before use.
INT8_GEMM_TABLE = (
    # decode GEMV / micro-batch: M is tiny — keep all of M resident and
    # maximize the stationary weight tile, K-deep first (the paper's
    # in-array reduction priority)
    ("decode-gemv", lambda M, N, K: M <= 16, (16, 512, 1024)),
    # batched decode: M fits one MXU pass, weight tile still the point
    ("decode-batch", lambda M, N, K: M <= 128, (128, 512, 1024)),
    # skinny outputs (down-projections): N is small, stream deep K
    ("skinny-n", lambda M, N, K: N <= 256, (256, 256, 2048)),
    # prefill / large-M: balanced tiles, psum pressure bounds block_m
    ("prefill-wide", lambda M, N, K: True, (256, 512, 512)),
)


def int8_gemm_vmem_bytes(bm: int, bn: int, bk: int, act_bytes: int = 2,
                         w_bytes: int = 1) -> int:
    """VMEM claim of one int8-GEMM grid step: activation (bm x bk) +
    weight tile (bk x bn) + f32 output window and scratch accumulator
    (2 x bm x bn)."""
    return (bm * bk * act_bytes + bk * bn * w_bytes
            + 2 * bm * bn * PSUM_BYTES)


def int8_gemm_blocks(M: int, N: int, K: int,
                     vmem: int = VMEM_BUDGET) -> tuple[int, int, int]:
    """(block_m, block_n, block_k) for `kernels.int8_gemm` from the
    autotune table, analytic `choose_blocks` as the fallback."""
    for _name, pred, (bm, bn, bk) in INT8_GEMM_TABLE:
        if pred(M, N, K):
            bm = _largest_divisor_leq(M, min(bm, M))
            bn = _largest_divisor_leq(N, min(bn, N))
            bk = _largest_divisor_leq(K, min(bk, K))
            if int8_gemm_vmem_bytes(bm, bn, bk) <= vmem:
                return bm, bn, bk
            break       # pinned entry busts the budget on this shape
    return choose_blocks(M, N, K, vmem=vmem)


def autotune_report(shapes=((8, 512, 256), (8, 256, 2048),
                            (1024, 1024, 1024), (4096, 128, 512))
                    ) -> list[dict]:
    """Table decisions for exemplar GEMM shapes (docs / tests surface):
    which entry matched, the legalized blocks, and their VMEM claim."""
    rows = []
    for M, N, K in shapes:
        entry = next((n for n, pred, _ in INT8_GEMM_TABLE
                      if pred(M, N, K)), None)
        bm, bn, bk = int8_gemm_blocks(M, N, K)
        rows.append({"shape": (M, N, K), "entry": entry,
                     "blocks": (bm, bn, bk),
                     "vmem_kib": int8_gemm_vmem_bytes(bm, bn, bk) // 1024,
                     "grid_steps": (-(-M // bm)) * (-(-N // bn))
                     * (-(-K // bk))})
    return rows


# Rows-per-grid-step ladder for the fused sweep kernel.
SWEEP_ROW_LADDER = (1024, 2048, 4096, 8192, 16384)


def sweep_block_rows(n_rows: int, n_fields: int, n_out_fields: int,
                     vmem: int = 2 * VMEM_BUDGET) -> int:
    """Rows per `sweep_eval` grid step: the smallest ladder entry that
    covers the batch in ONE grid step, capped so the per-step field
    matrix + output matrix + ~2x elementwise temporaries (all f32) stay
    inside the VMEM budget.  Batches beyond the cap stream in multiple
    grid steps of the largest fitting block."""
    per_row = 4 * (n_fields + n_out_fields) * 3
    cap = max(SWEEP_ROW_LADDER[0], vmem // per_row)
    best = SWEEP_ROW_LADDER[0]
    for r in SWEEP_ROW_LADDER:
        if r > cap:
            break
        best = r
        if r >= n_rows:
            break
    return best
