"""Flash-decoding Pallas TPU kernel: one query token vs a long KV cache.

Grid (batch*heads, S/block_kv) with online-softmax partials in VMEM —
linear in cache length, the TPU counterpart of serving long_500k decode.
A `length` scalar masks the invalid cache tail (prefetched via scalar
grid arguments in SMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_kv: int, n_kv: int):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)             # (1, d)
    k = k_ref[0].astype(jnp.float32)             # (bkv, d)
    v = v_ref[0].astype(jnp.float32)
    d = q.shape[-1]
    s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, bkv)
    s = s * (1.0 / (d ** 0.5))
    pos = kj * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_kv), 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _done():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, length, *, block_kv: int = 512,
                     interpret: bool = False):
    """q: (bh, 1, d); caches: (bh, S, d); length: () int32 valid prefix.
    Returns (bh, 1, d)."""
    bh, one, d = q.shape
    S = k_cache.shape[1]
    bkv = min(block_kv, S)
    assert S % bkv == 0
    n_kv = S // bkv
    length = jnp.asarray(length, jnp.int32).reshape((1,))

    return pl.pallas_call(
        functools.partial(_kernel, block_kv=bkv, n_kv=n_kv),
        grid=(bh, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(length, q, k_cache, v_cache)
