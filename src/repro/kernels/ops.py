"""Jit'd public wrappers around the Pallas kernels.

On CPU (tests, dry-run container) the kernels execute via interpret mode;
on TPU they compile to Mosaic.  The wrappers handle GQA head folding and
block-size selection through the WWW mapping adapter.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import decode_attention as _da
from . import flash_attention as _fa
from . import int8_gemm as _ig


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("dataflow", "block_m", "block_n",
                                   "block_k", "interpret"))
def int8_matmul(x, w_q, w_scale, dataflow: str = "os",
                block_m: int = 0, block_n: int = 0, block_k: int = 0,
                interpret: bool | None = None):
    """y = x @ dequant(w_q); blocks from the autotune table (VMEM-aware
    shape-class entries, analytic WWW-adapter choice as fallback)."""
    from .autotune import int8_gemm_blocks
    if interpret is None:
        interpret = _on_cpu()
    M, K = x.shape
    N = w_q.shape[1]
    if not (block_m and block_n and block_k):
        block_m, block_n, block_k = int8_gemm_blocks(M, N, K)
    return _ig.int8_gemm(x, w_q, w_scale, block_m=block_m,
                         block_n=block_n, block_k=block_k,
                         dataflow=dataflow, interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                   "block_kv", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool | None = None):
    """q: (b, sq, H, d); k/v: (b, sk, KV, d) GQA.  Returns (b, sq, H, d)."""
    if interpret is None:
        interpret = _on_cpu()
    b, sq, nh, d = q.shape
    kv = k.shape[2]
    if kv != nh:
        k = jnp.repeat(k, nh // kv, axis=2)
        v = jnp.repeat(v, nh // kv, axis=2)
    fold = lambda t: t.swapaxes(1, 2).reshape(b * nh, t.shape[1], d)
    o = _fa.flash_attention(fold(q), fold(k), fold(v), causal=causal,
                            window=window, block_q=block_q,
                            block_kv=block_kv, interpret=interpret)
    return o.reshape(b, nh, sq, d).swapaxes(1, 2)


@partial(jax.jit, static_argnames=("block_kv", "interpret"))
def decode_attention(q, k_cache, v_cache, length, block_kv: int = 512,
                     interpret: bool | None = None):
    """q: (b, 1, H, d); caches: (b, S, KV, d); length: () int32."""
    if interpret is None:
        interpret = _on_cpu()
    b, one, nh, d = q.shape
    kv = k_cache.shape[2]
    if kv != nh:
        k_cache = jnp.repeat(k_cache, nh // kv, axis=2)
        v_cache = jnp.repeat(v_cache, nh // kv, axis=2)
    fold = lambda t: t.swapaxes(1, 2).reshape(b * nh, t.shape[1], d)
    o = _da.decode_attention(fold(q), fold(k_cache), fold(v_cache), length,
                             block_kv=block_kv, interpret=interpret)
    return o.reshape(b, nh, 1, d).swapaxes(1, 2)
