"""Fused Pallas kernel for the planner sweep inner loop.

The sweep engine's hot path scores flattened (GEMM, config, mapping) rows
— all 6 unrolled DRAM loop orders, revisit/coverage factors, greedy-mask
order selection and the in-kernel argmin over orders — through
`vectorized.evaluate_flat`, relying on XLA to fuse the ~200-op elementwise
graph.  This kernel runs the SAME backend-shared cost spec
(vectorized.cim_cast / cim_row_terms / cim_best_order / cim_outputs)
inside one hand-written `pl.pallas_call`: every intermediate lives in
VMEM for the whole pass, one grid step per block of rows, so nothing
round-trips to HBM between the 6 order evaluations (the ROADMAP's
"measure whether hand-written Pallas beats XLA fusion at large batch").

Layout: the B rows are stacked as a (len(FLAT_FIELDS), B) float32 matrix
— fields on the sublane axis, rows on the lane axis — so a block is a
(F, block_rows) tile and each field is one (1, block_rows) row slice.
Outputs come back as a (len(SWEEP_OUT_FIELDS), B) matrix, unpacked to the
same dict `evaluate_flat` returns (bit-identical semantics; `valid` is
carried as 0/1 float32 through the kernel and re-boolified outside).

Platform handling mirrors kernels/ops.py: interpret mode on CPU (tests,
CI containers), compiled Mosaic on TPU.  `pallas_status()` probes the
lowering once per process; platforms where neither works report
mode="unavailable" with the lowering error, and the sweep engine falls
back to the XLA kernel, recording the reason in `cache_info()`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core.cost_model import DRAM_STREAM_EFFICIENCY
from ..core.loopnest import check_order_mode
from ..core.vectorized import (FLAT_FIELDS, cim_best_order, cim_cast,
                               cim_outputs, cim_row_terms)

# Kernel output rows, in stacking order — the same keys (and per-row
# values) evaluate_flat returns.
SWEEP_OUT_FIELDS = ("valid", "energy_pj", "time_ns", "tops_per_w",
                    "gflops", "utilization", "compute_ns", "dram_ns",
                    "smem_ns", "dram_bytes", "smem_bytes")

# Reference rows-per-grid-step.  VMEM footprint is (len(FLAT_FIELDS) +
# len(SWEEP_OUT_FIELDS)) * block * 4B ≈ 1 MB at 8192 plus intermediates —
# comfortably under the ~16 MB/core budget, and big enough that the
# full-workload planner batch (~8k rows) runs in a single grid step.
# The default is now autotuned per batch (kernels.autotune
# .sweep_block_rows): small batches take the smallest single-grid-step
# ladder entry, campaign-scale batches stream at the largest
# VMEM-fitting block.
_BLOCK_ROWS = 8192


def _sweep_kernel(in_ref, out_ref, *, order_mode: str, dram_eff: float):
    """One block: fields are (1, block) row slices of the input tile; the
    whole cost spec — terms, 6-order unroll, selection, outputs — runs on
    VMEM-resident values."""
    cols = {f: in_ref[i:i + 1, :] for i, f in enumerate(FLAT_FIELDS)}
    pre = cim_row_terms(cim_cast(cols))
    best_energy, best_dram = cim_best_order(pre, order_mode)
    out = cim_outputs(pre, best_energy, best_dram, dram_eff)
    for j, name in enumerate(SWEEP_OUT_FIELDS):
        out_ref[j:j + 1, :] = out[name].astype(jnp.float32)


def sweep_eval(batch: dict, order_mode: str = "exact",
               dram_eff: float = DRAM_STREAM_EFFICIENCY,
               block_rows: int | None = None,
               interpret: bool | None = None) -> dict:
    """Pallas-fused equivalent of `vectorized.evaluate_flat`.

    batch: dict of (B,) arrays for every name in FLAT_FIELDS; returns the
    same dict of (B,) arrays (valid as bool).  Rows are padded (edge
    replication) to a multiple of `block_rows` and the padding is sliced
    off before returning.  block_rows=None autotunes it from the batch
    size and the VMEM budget (kernels.autotune.sweep_block_rows); block
    choice never changes the values, only the grid decomposition.
    """
    check_order_mode(order_mode)
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    rows = jnp.stack([jnp.asarray(batch[f]).astype(jnp.float32)
                      for f in FLAT_FIELDS])
    b = rows.shape[1]
    if block_rows is None:
        from .autotune import sweep_block_rows
        block_rows = sweep_block_rows(b, len(FLAT_FIELDS),
                                      len(SWEEP_OUT_FIELDS))
    blk = min(block_rows, max(1, b))
    m = -(-b // blk) * blk
    if m != b:
        rows = jnp.pad(rows, ((0, 0), (0, m - b)), mode="edge")
    out = pl.pallas_call(
        functools.partial(_sweep_kernel, order_mode=order_mode,
                          dram_eff=dram_eff),
        grid=(m // blk,),
        in_specs=[pl.BlockSpec((len(FLAT_FIELDS), blk), lambda i: (0, i))],
        out_specs=pl.BlockSpec((len(SWEEP_OUT_FIELDS), blk),
                               lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((len(SWEEP_OUT_FIELDS), m),
                                       jnp.float32),
        interpret=interpret,
    )(rows)
    res = {name: out[j, :b] for j, name in enumerate(SWEEP_OUT_FIELDS)}
    res["valid"] = res["valid"] > 0.5
    return res


# --- platform probe ----------------------------------------------------------

_STATUS: dict | None = None


def pallas_status() -> dict:
    """How this process can run the sweep kernel, probed once:

      {"mode": "interpret" | "compiled" | "unavailable", "reason": ...}

    CPU always takes interpret mode (the repo-wide Pallas convention, see
    kernels/ops.py — the kernel logic is exercised, execution is emulated).
    Accelerators probe an 8-row compiled lowering; a platform whose Pallas
    pipeline cannot lower the kernel reports "unavailable" with the error,
    and the sweep engine falls back to the XLA backend, recording the
    reason in its cache telemetry (`SweepEngine.cache_info()`).
    """
    global _STATUS
    if _STATUS is None:
        platform = jax.default_backend()
        if platform == "cpu":
            _STATUS = {"mode": "interpret",
                       "reason": "cpu: compiled Mosaic lowering is "
                                 "TPU-only; kernel runs via interpret "
                                 "mode"}
        else:
            try:
                probe = {f: np.ones(8, np.float32) for f in FLAT_FIELDS}
                out = jax.jit(functools.partial(
                    sweep_eval, interpret=False))(probe)
                jax.block_until_ready(out["energy_pj"])
                _STATUS = {"mode": "compiled", "reason": None}
            except Exception as e:  # lowering/runtime failure -> XLA path
                _STATUS = {"mode": "unavailable",
                           "reason": f"{platform}: {type(e).__name__}: "
                                     f"{e}"[:300]}
    return _STATUS


def _reset_status_for_tests() -> None:
    """Drop the memoized probe result (test hook only)."""
    global _STATUS
    _STATUS = None
