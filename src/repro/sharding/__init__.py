"""Sharding rules (DP/FSDP/TP/EP + cache SP)."""
from .rules import batch_specs, cache_specs, param_specs, to_named

__all__ = ["param_specs", "batch_specs", "cache_specs", "to_named"]
