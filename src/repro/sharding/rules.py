"""Named sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Scheme (single pod (data, model); multi-pod adds a leading "pod" axis that
joins the data-parallel group):
  * TP over "model": attention heads / FFN hidden / experts / vocab.
  * FSDP over "data" (optional, rc.fsdp): the non-TP dim of every large
    weight is sharded over the data axis; XLA inserts the all-gathers.
  * Batch over ("pod","data"); decode KV caches shard sequence over
    "model" (flash-decoding style) and batch over "data".

Rules match on (leaf name, ndim) — stacked layer params carry a leading
period dimension that is never sharded.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, RunConfig


def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _param_rule(name: str, ndim: int, cfg: ModelConfig, rc: RunConfig,
                parent: str) -> P:
    fsdp = "data" if rc.fsdp else None
    tp = "model"
    ep_ok = cfg.moe and cfg.moe.n_experts % 16 == 0

    # --- embeddings / heads ---
    if name == "embed":
        return P(None, tp, fsdp) if ndim == 3 else P(tp, fsdp)
    if name == "lm_head":
        return P(None, fsdp, tp) if ndim == 3 else P(fsdp, tp)

    # --- MoE expert banks: 4D (period, E, in, out) ---
    if ndim == 4 and name in ("w_gate", "w_up", "w_down"):
        if ep_ok:
            return P(None, tp, fsdp, None)          # expert parallel
        if name == "w_down":
            return P(None, None, tp, fsdp)          # TP inside expert
        return P(None, None, fsdp, tp)
    if name == "router":
        return P(None, None, None)

    # --- column-parallel (d -> hidden) ---
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "w_z", "w_x", "w_dt"):
        return P(None, fsdp, tp)
    # --- row-parallel (hidden -> d) ---
    if name in ("wo", "w_down", "out_proj"):
        return P(None, tp, fsdp)
    # --- small replicated projections ---
    if name in ("w_B", "w_C"):
        return P(None, fsdp, None)
    if name in ("conv_x",):
        return P(None, None, tp)
    if name in ("conv_B", "conv_C"):
        return P(None, None, None)
    # --- vectors ---
    if name in ("bq", "bk", "bv", "norm_scale"):
        return P(None, tp)
    if name in ("A_log", "dt_bias", "D"):
        return P(None, tp)
    if name == "scale":      # rmsnorm over d_model (replicated activations)
        return P(*([None] * ndim))
    return P(*([None] * ndim))


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def param_specs(tree_shapes, cfg: ModelConfig, rc: RunConfig):
    """PartitionSpec tree for a params (or optimizer-state) shape tree.

    Optimizer moments nest the param path (m/..., v/.../vr): the rule key
    is the innermost *weight* name on the path; adafactor's factored vr/vc
    drop the corresponding trailing dims of the parent spec.
    """
    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        factored = None
        if name in ("vr", "vc") and len(names) >= 2:
            factored, name = name, names[-2]
        ndim = leaf.ndim + (1 if factored else 0)
        spec = _param_rule(name, ndim, cfg, rc, names[-2] if
                           len(names) >= 2 else "")
        if factored == "vr":      # parent spec minus last dim
            spec = P(*spec[:-1])
        elif factored == "vc":    # parent spec minus second-to-last dim
            spec = P(*(spec[:-2] + spec[-1:]))
        if len(spec) != leaf.ndim:
            # scalars (step) and anything unmatched: replicate
            spec = P(*([None] * leaf.ndim))
        return spec

    return jax.tree_util.tree_map_with_path(spec_for, tree_shapes)


def batch_specs(tree_shapes, mesh: Mesh):
    """Shard every batch leaf's leading dim over (pod, data)."""
    ba = batch_axes(mesh)

    def spec_for(path, leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.shape[0] == 1:      # un-shardable singleton batch
            return P(*([None] * leaf.ndim))
        return P(ba, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec_for, tree_shapes)


def cache_specs(tree_shapes, mesh: Mesh, cfg: ModelConfig,
                seq_shard: bool = True):
    """KV/state cache specs: (period, batch, S, kv, dh) — batch over
    "data", sequence over "model" (flash-decoding SP) when batch alone
    cannot saturate the mesh; mamba states shard heads over "model"."""
    ba_all = batch_axes(mesh)        # ("pod","data") on the multi-pod mesh

    def _baxis(b: int):
        """Largest batch-axis tuple that divides the cache batch."""
        axes = list(ba_all)
        while axes:
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            if b % total == 0:
                return tuple(axes) if len(axes) > 1 else axes[0]
            axes.pop(0)              # drop "pod" first
        return None

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v", "k_scale", "v_scale"):
            baxis = _baxis(leaf.shape[1])
            saxis = "model" if seq_shard else None
            rest = [None] * (leaf.ndim - 3)
            return P(None, baxis, saxis, *rest)
        if name == "state":         # (period, b, nh, n, p)
            return P(None, _baxis(leaf.shape[1]), "model", None, None)
        if name == "conv":          # (period, b, k-1, channels)
            return P(None, _baxis(leaf.shape[1]), None, None)
        return P(*([None] * leaf.ndim))
    return jax.tree_util.tree_map_with_path(spec_for, tree_shapes)


def legalize(spec_tree, shape_tree, mesh: Mesh):
    """Drop mesh axes from any spec dim that does not divide the global
    dim size (pjit argument shardings require exact divisibility; e.g.
    mamba2's vocab 50280 cannot shard 16-way and falls back to
    replicated-on-that-dim)."""
    def fix(spec, leaf):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        out = []
        for size, ax in zip(leaf.shape, dims):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= mesh.shape[a]
            out.append(ax if size % total == 0 else None)
        return P(*out)
    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_named(mesh: Mesh, spec_tree, shape_tree=None):
    if shape_tree is not None:
        spec_tree = legalize(spec_tree, shape_tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
