"""Reproduction of "WWW: What, When, Where to Compute-in-Memory" grown
into a jax/pallas planning + serving stack.

Layers (see docs/architecture.md for the map and dataflow):

* `repro.core` — GEMM taxonomy, scalar + vectorized cost models, the
  batched sweep engine, and the What/When/Where planner.
* `repro.kernels` — hand-written Pallas kernels (sweep inner loop, INT8
  GEMM, attention).
* `repro.launch` — meshes (single-host and jax.distributed multi-host),
  dry-run driver, roofline, serve/train CLIs, report rendering.
* `repro.models` / `repro.serving` / `repro.quant` — reduced LM
  architectures and the planner-gated INT8 serving session.
* `repro.train` / `repro.optim` / `repro.data` / `repro.sharding` —
  the training substrate.
"""
