"""Compile dry-run cell JSONs into the EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.launch.report results/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load_cells(outdir: str, tag: str = "") -> list[dict]:
    """tag='' loads only baseline cells (mesh part has no -variant
    suffix); tag='xyz' loads only '<mesh>-xyz' variants."""
    cells = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        base = os.path.basename(path)[:-5]
        parts = base.split(".")
        if len(parts) < 3:
            continue
        mesh_part = parts[2]
        cell_tag = mesh_part.split("-", 1)[1] if "-" in mesh_part else ""
        if cell_tag != tag:
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(cells: list[dict]) -> str:
    lines = ["| arch | shape | mesh | status | compile | HBM args/dev |",
             "|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] == "ok":
            mem = c.get("memory_analysis", {})
            args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok | "
                f"{c.get('compile_s', '?')}s | {args_gb:.2f} GB |")
        elif c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                         f"skipped (sub-quadratic rule) | — | — |")
        else:
            err = c.get("error", "?")[:60]
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                         f"ERROR: {err} | — | — |")
    return "\n".join(lines)


def roofline_table(cells: list[dict], mesh: str = "single") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck |"
        " useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] != "ok" or c["mesh"] != mesh:
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_fraction']:.2f} | "
            f"**{r['roofline_fraction']:.3f}** |")
    return "\n".join(lines)


def planner_cache_table(cells: list[dict]) -> str:
    """Per-decode-cell what/when/where summary + sweep-cache telemetry
    (repro.core.sweep LRU hit/miss counters recorded at dry-run time —
    the cache-sizing signal for serving traffic)."""
    lines = ["| arch | shape | mesh | cim frac | cim routed | "
             "energy gain | plan hits/misses | engine cache |",
             "|---|---|---|---|---|---|---|---|"]
    found = False
    for c in cells:
        p = c.get("planner")
        if c["status"] != "ok" or not p:
            continue
        found = True
        s = p["summary"]
        eng = p["cache"]
        # executed-route fraction: how many projections the gated decode
        # step actually lowers to the CiM INT8 path (older cell JSONs
        # predate the routing block)
        routed = (f"{p['cim_routed_fraction']:.2f}"
                  if "cim_routed_fraction" in p else "-")
        # per-backend keyspace breakdown + pallas fallback marker (older
        # cell JSONs predate both fields)
        backends = " ".join(f"{b}:{v['hits']}h/{v['misses']}m"
                            for b, v in sorted(
                                (eng.get("backends") or {}).items()))
        if eng.get("pallas_fallback"):
            backends = (backends + " pallas→xla").strip()
        engine_cell = f"{eng['hits']}h/{eng['misses']}m size={eng['size']}"
        if backends:
            engine_cell += f" [{backends}]"
        # streaming-enumerator accounting (cells predating chunked
        # evaluation, or whole-batch engines, carry no tile count)
        ch = eng.get("chunks") or {}
        if ch.get("chunk_rows"):
            engine_cell += (f" chunks={ch.get('evaluated', 0)}"
                            f"@{ch['chunk_rows']}rows")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{s['cim_fraction']:.2f} | {routed} | "
            f"{s['energy_gain_x']:.2f}x | "
            f"{p['plan_hits']}/{p['plan_misses']} | "
            f"{engine_cell} |")
    return "\n".join(lines) if found else "(no decode cells with planner telemetry)"


def shard_balance_table(cells: list[dict]) -> str:
    """Per-host telemetry of distributed sweep runs: each process's
    engine cache hit/miss (SPMD — every host keeps its own LRU with
    identical contents, so a divergent column is a bug signal) plus the
    row shard balance of the padded batches (a skewed balance means an
    uneven device set is bottlenecked on its largest host).

    Cells whose planner block ran on a single-host mesh carry
    `cache.distributed = None` and are skipped."""
    lines = ["| arch | shape | host | procs | devices | host cache | "
             "rows/process |",
             "|---|---|---|---|---|---|---|"]
    found = False
    for c in cells:
        p = c.get("planner")
        if c.get("status") != "ok" or not p:
            continue
        eng = p.get("cache") or {}
        d = eng.get("distributed")
        if not d:
            continue
        found = True
        balance = " ".join(f"p{k}:{v}" for k, v in
                           sorted(d.get("shard_balance", {}).items()))
        lines.append(
            f"| {c['arch']} | {c['shape']} | "
            f"p{d['process_index']}/{d['processes']} | "
            f"{d['processes']} | {d.get('mesh_devices', '?')} | "
            f"{eng['hits']}h/{eng['misses']}m | {balance} |")
    return ("\n".join(lines) if found
            else "(no distributed sweep telemetry in these cells)")


def serve_traffic_table(bench: dict) -> str:
    """Throughput-vs-latency rows from BENCH_serve.json's `traffic`
    block (the continuous-batching open-loop bench): one row per
    arrival rate, TTFT percentiles against engine tokens/s, plus the
    scheduler health columns (queue depth, slot occupancy, evictions).
    The fixed-batch reference row anchors the curves against the legacy
    lockstep session on the same core."""
    t = bench.get("traffic")
    if not t:
        return "(no traffic block in BENCH_serve.json — run " \
               "benchmarks.serve_traffic_bench)"
    lines = [f"arch={t['arch']} slots={t['n_slots']} "
             f"block_size={t['block_size']} "
             f"requests/rate={t['requests_per_rate']} seed={t['seed']}",
             "",
             "| arrival req/s | TTFT p50 | TTFT p95 | engine tok/s | "
             "req tok/s | occupancy | queue depth | evict |",
             "|---|---|---|---|---|---|---|---|"]
    for c in t.get("curves", []):
        lines.append(
            f"| {c['arrival_rate_req_per_s']:g} | "
            f"{fmt_s(c['ttft_p50_s'])} | {fmt_s(c['ttft_p95_s'])} | "
            f"{c['engine_tokens_per_s']:.1f} | "
            f"{c['request_tokens_per_s_mean']:.1f} | "
            f"{c['slot_occupancy_mean']:.2f} | "
            f"{c['queue_depth_mean']:.2f} | {c['evictions']} |")
    ref = t.get("fixed_batch_reference_tokens_per_s")
    if ref is not None:
        lines.append(f"\nfixed-batch reference (legacy lockstep, "
                     f"batch={t['n_slots']}): {ref:.1f} tok/s")
    return "\n".join(lines)


def serve_step_breakdown_table(bench: dict) -> str:
    """Decode hot-path health from the `traffic` block's per-rate
    `decode_step_breakdown`: where each step's host budget went
    (device dispatch vs blocking host fetch vs telemetry sampling),
    whether the loop ran pipelined (host fetch of step t overlapped
    with step t+1's compute), and whether KV-cache buffer donation took
    effect (no per-token pool copy; "off" = donation disabled, the CPU
    default)."""
    t = bench.get("traffic")
    curves = (t or {}).get("curves", [])
    if not any("decode_step_breakdown" in c for c in curves):
        return "(no decode_step_breakdown in BENCH_serve.json traffic " \
               "curves — regenerate with benchmarks.serve_traffic_bench)"
    lines = ["| arrival req/s | steps | pipelined | donation | "
             "dispatch/step | fetch/step | telemetry/step |",
             "|---|---|---|---|---|---|---|"]
    for c in curves:
        b = c.get("decode_step_breakdown")
        if not b:
            continue
        don = c.get("kv_donation_ok")
        lines.append(
            f"| {c['arrival_rate_req_per_s']:g} | {b['steps']} | "
            f"{'yes' if b['pipelined'] else 'no'} | "
            f"{'ok' if don else ('off' if don is None else 'FAIL')} | "
            f"{b['dispatch_ms_per_step']:.2f}ms | "
            f"{b['host_fetch_ms_per_step']:.2f}ms | "
            f"{b['telemetry_ms_per_step']:.2f}ms |")
    return "\n".join(lines)


def serve_adaptive_table(bench: dict) -> str:
    """Adaptive-planning rows from BENCH_serve.json's `adaptive` block
    (benchmarks.serve_adaptive_bench): adaptive vs frozen-plan engine
    throughput, the hot-swap counters of the forced-flip scenario, and
    the per-bucket hit/build/flip table of the plan service."""
    a = bench.get("adaptive")
    if not a:
        return "(no adaptive block in BENCH_serve.json — run " \
               "benchmarks.serve_adaptive_bench)"
    lines = [f"arch={a['arch']} slots={a['n_slots']} "
             f"requests={a['requests']} seed={a['seed']}",
             "",
             "| mode | engine tok/s | plan swaps | verdict flips | "
             "executables | swap mean | swap max |",
             "|---|---|---|---|---|---|---|"]
    for mode in ("no_flip", "forced_flip"):
        s = a.get(mode)
        if not s:
            continue
        lat = s.get("swap_latency_s") or {}
        mean = lat.get("mean")
        mx = lat.get("max")
        lines.append(
            f"| {mode.replace('_', '-')} | "
            f"{s['engine_tokens_per_s']:.1f} | {s['plan_swaps']} | "
            f"{s['verdict_flips']} | {s['decode_executables']} | "
            f"{fmt_s(mean) if mean else '—'} | "
            f"{fmt_s(mx) if mx else '—'} |")
    frozen = a.get("frozen_tokens_per_s")
    if frozen is not None:
        lines.append(f"\nfrozen-plan reference engine: {frozen:.1f} tok/s")
    buckets = ((a.get("forced_flip") or {}).get("service") or {}) \
        .get("buckets") or {}
    if buckets:
        lines += ["", "| bucket | hits | misses | builds | flips | "
                  "plan digest |", "|---|---|---|---|---|---|"]
        for name, b in buckets.items():
            lines.append(
                f"| {name} | {b['hits']} | {b['misses']} | "
                f"{b['builds']} | {b['flips']} | {b['table_digest']} |")
    return "\n".join(lines)


def campaign_table(report: dict) -> str:
    """Campaign summary from results/campaign/campaign_report.json
    (repro.launch.campaign): grid provenance, constraint accounting,
    and the certification gate's verdict per champion design point."""
    if not report:
        return "(no campaign report — run " \
               "python -m repro.launch.campaign)"
    r = report.get("report", {})
    spec = r.get("spec", {})
    stats = r.get("stats", {})
    fr = report.get("frontier_csv", {})
    lines = [
        f"grid: {spec.get('n_points', '?')} points "
        f"({len(spec.get('workloads', []))} cells x "
        f"{spec.get('n_units', '?')} units), "
        f"digest {spec.get('digest', '?')}, "
        f"backend {r.get('group_by', '?')}/{r.get('backend', '?')}",
        f"frontier: {fr.get('rows', '?')} rows, "
        f"sha256 {str(fr.get('sha256', '?'))[:16]}",
    ]
    filt = stats.get("constraint_filtered") or {}
    if filt:
        lines.append("contracts: " + ", ".join(
            f"{spec_} filtered {n}" for spec_, n in filt.items()))
    cert = report.get("certification") or {}
    pts = cert.get("points") or []
    if pts:
        lines += ["",
                  "| group | champion config | order | bitwise | "
                  "contracts | CiM deployed |",
                  "|---|---|---|---|---|---|"]
        for p in pts:
            pl = p.get("planner", {})
            lines.append(
                f"| {p['group']} | {p['config']} | {p['order_mode']} | "
                f"{'ok' if p['bitwise_ok'] else 'FAIL'} | "
                f"{'ok' if p['contracts_ok'] else 'FAIL'} | "
                f"{pl.get('n_use_cim', '?')}/{p.get('n_gemms', '?')} |")
        lines.append(f"\ncertification: "
                     f"{'OK' if cert.get('ok') else 'FAILED'} "
                     f"({len(pts)} champion points)")
    return "\n".join(lines)


def summarize(cells: list[dict]) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    worst = sorted((c for c in ok if c["mesh"] == "single"),
                   key=lambda c: c["roofline"]["roofline_fraction"])
    coll = sorted((c for c in ok if c["mesh"] == "single"),
                  key=lambda c: -c["roofline"]["collective_s"])
    return {
        "n_ok": len(ok), "n_skipped": len(skipped), "n_error": len(err),
        "errors": [(c["arch"], c["shape"], c["mesh"]) for c in err],
        "worst_fraction": [(c["arch"], c["shape"],
                            round(c["roofline"]["roofline_fraction"], 4))
                           for c in worst[:5]],
        "most_collective_bound": [
            (c["arch"], c["shape"],
             round(c["roofline"]["collective_s"], 3)) for c in coll[:5]],
    }


if __name__ == "__main__":
    outdir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load_cells(outdir)
    print("## Dry-run status\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single pod, 256 chips)\n")
    print(roofline_table(cells, "single"))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(cells, "multi"))
    print("\n## Planner (decode cells: what/when/where + sweep cache)\n")
    print(planner_cache_table(cells))
    print("\n## Distributed sweeps (per-host cache + shard balance)\n")
    print(shard_balance_table(cells))
    bench_path = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
    if os.path.exists(bench_path):
        with open(bench_path) as f:
            bench = json.load(f)
        print("\n## Serving traffic (continuous batching, "
              "throughput vs latency)\n")
        print(serve_traffic_table(bench))
        print("\n## Decode step breakdown (dispatch vs host fetch vs "
              "telemetry)\n")
        print(serve_step_breakdown_table(bench))
        print("\n## Adaptive planning (bucket hit rates, verdict "
              "flips, plan swaps)\n")
        print(serve_adaptive_table(bench))
    campaign_path = os.environ.get("CAMPAIGN_REPORT",
                                   "results/campaign/campaign_report.json")
    if os.path.exists(campaign_path):
        with open(campaign_path) as f:
            campaign = json.load(f)
        print("\n## Design-space campaign (Pareto fronts + "
              "certification)\n")
        print(campaign_table(campaign))
    print("\n## Summary\n")
    print(json.dumps(summarize(cells), indent=1))
