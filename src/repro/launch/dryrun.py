"""Multi-pod dry-run driver (deliverable e).

Lowers + compiles train_step / serve_step for every (arch x input-shape x
mesh) cell against the production meshes — 16x16 single pod and 2x16x16
multi-pod — using ShapeDtypeStruct stand-ins (no allocation).  Prints
memory_analysis (fits?) and cost_analysis (FLOPs/bytes for §Roofline),
parses the partitioned HLO for collective bytes, and writes one JSON per
cell so an interrupted sweep resumes where it stopped.

Compatibility: Compiled.cost_analysis() returns a flat dict on older jax
and a list of per-computation dicts on newer jax; _normalize_cost_analysis
folds both shapes into one dict before any key lookup.

Cost accounting: XLA's cost_analysis counts a while-loop body once, so the
scanned layer stack under-reports FLOPs/bytes/collectives.  Each cell
therefore gets (a) the official scanned compile — the deployment program,
proves lowering + memory — and (b) two partial-unroll compiles whose costs
extrapolate linearly to the full layer count (see _unroll_points).

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \
      --mesh single --out results/dryrun
  python -m repro.launch.dryrun --all --out results/dryrun
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, RunConfig
from ..configs.base import ModelConfig, ShapeConfig
from ..models import forward
from ..models.model import n_periods
from ..optim import make_optimizer
from ..serving.engine import make_serve_step
from ..sharding.rules import (batch_specs, cache_specs, param_specs,
                              to_named)
from ..train.loop import make_train_step
from . import specs as S
from .hlo_analysis import collective_stats, op_census
from .mesh import make_production_mesh, single_pod_mesh_from
from .roofline import Roofline, analytic_hbm_bytes, model_flops

from jax.sharding import NamedSharding, PartitionSpec as P


def run_config_for(cfg: ModelConfig, shape: ShapeConfig,
                   overrides: dict | None = None) -> RunConfig:
    """Per-cell runtime policy (recorded in the cell JSON)."""
    params = cfg.param_count()
    opt = "adafactor" if params > 100e9 else "adamw"
    micro = 4 if (shape.kind == "train" and cfg.d_model >= 5120) else 1
    # int8 KV cache when a bf16 cache would not fit per-device HBM
    kv_dtype = "bfloat16"
    if shape.kind == "decode":
        n_attn = (cfg.n_layers // cfg.attn_every
                  if cfg.family == "hybrid" else cfg.n_layers)
        if cfg.family == "ssm":
            n_attn = 0
        cache_bytes = (2 * n_attn * shape.global_batch * shape.seq_len
                       * cfg.n_kv_heads * cfg.head_dim() * 2)
        if cache_bytes / 256 > 6e9:
            kv_dtype = "int8"
    rc = RunConfig(optimizer=opt, microbatches=micro, remat=True,
                   fsdp=True, kv_cache_dtype=kv_dtype,
                   attn_impl="flash_jnp", attn_chunk=2048)
    if overrides:
        rc = dataclasses.replace(rc, **overrides)
    return rc


def _mesh(kind: str):
    if kind == "multi":
        return make_production_mesh(multi_pod=True), 512
    # single pod: 16x16 slice of the 512 host devices
    return single_pod_mesh_from(jax.devices()), 256


def _build(cfg, shape, mesh, rc):
    """Returns (jitted_fn, abstract_args) for this cell."""
    pshapes = S.param_shapes(cfg)
    pspecs = param_specs(pshapes, cfg, rc)
    psh = to_named(mesh, pspecs, pshapes)

    if shape.kind == "train":
        opt_init, _ = make_optimizer(rc.optimizer)
        oshapes = jax.eval_shape(opt_init, pshapes)
        ospecs = param_specs(oshapes, cfg, rc)
        osh = to_named(mesh, ospecs, oshapes)
        binput = S.train_input_specs(cfg, shape)
        bsh = to_named(mesh, batch_specs(binput, mesh), binput)
        step = make_train_step(cfg, rc)
        jitted = jax.jit(
            step,
            in_shardings=(psh, osh, bsh, NamedSharding(mesh, P())),
            out_shardings=(psh, osh, None))
        return jitted, (pshapes, oshapes, binput,
                        jax.ShapeDtypeStruct((), jnp.int32))
    if shape.kind == "prefill":
        binput = S.prefill_input_specs(cfg, shape)
        bsh = to_named(mesh, batch_specs(binput, mesh), binput)

        def prefill(params, batch):
            logits, _ = forward(params, batch["tokens"], cfg, rc,
                                image_embeds=batch.get("image_embeds"))
            return logits
        out_sh = None
        if rc.shard_loss:
            # keep served logits batch+vocab sharded — out_shardings=None
            # replicates the (b, s, V) tensor to every device (§Perf)
            ba = tuple(a for a in rc.batch_axes.split(",") if a)
            ba = ba if len(ba) > 1 else ba[0]
            spec = (P(ba, None, None, "model") if cfg.family == "audio"
                    else P(ba, None, "model"))
            out_sh = NamedSharding(mesh, spec)
        jitted = jax.jit(prefill, in_shardings=(psh, bsh),
                         out_shardings=out_sh)
        return jitted, (pshapes, binput)
    # decode
    dins = S.decode_input_specs(cfg, rc, shape)
    csh = to_named(mesh, cache_specs(dins["cache"], mesh, cfg),
                   dins["cache"])
    tsh = to_named(mesh, batch_specs({"t": dins["tokens"]}, mesh))["t"]
    step = make_serve_step(cfg, rc)
    jitted = jax.jit(
        step,
        in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
        out_shardings=(None, csh))
    return jitted, (pshapes, dins["cache"], dins["tokens"], dins["pos"])


def _unroll_points(L: int) -> list[int]:
    """Layer-scan unroll factors for the cost-extrapolation compiles."""
    if L <= 4:
        return [L]
    divs = [d for d in range(1, L + 1) if L % d == 0]
    k1 = max(d for d in divs if d <= 8)
    smaller = [d for d in divs if d < k1 and d <= max(1, k1 // 2)]
    k2 = max(smaller) if smaller else 1
    return [k1, k2] if k1 > k2 else [k1]


def _extrapolate(measures: list, L: int) -> dict:
    """measured(k) = fixed + k*body => true(L)."""
    if len(measures) == 1:
        k, m = measures[0]
        if k == L:
            return dict(m)
        return {key: v * (L / max(1, k)) for key, v in m.items()}
    (k1, m1), (k2, m2) = measures
    out = {}
    for key in m1:
        body = (m1[key] - m2[key]) / (k1 - k2)
        out[key] = max(m1[key], m2[key] + (L - k2) * body)
    return out


def _normalize_cost_analysis(cost):
    """Normalize Compiled.cost_analysis() across jax versions.

    Older jax returns one flat dict; newer jax returns a list of
    per-computation dicts (usually length 1 — the entry computation).
    Returns a single dict: a lone entry is taken as-is, multiple entries
    are merged by summing numeric values per key (each computation's cost
    contributes to the program total).
    """
    if not cost:
        return {}
    if isinstance(cost, dict):
        return cost
    dicts = [c for c in cost if c]
    if not dicts:
        return {}
    if len(dicts) == 1:
        return dict(dicts[0])
    merged: dict = {}
    for c in dicts:
        for k, v in c.items():
            if isinstance(v, (int, float)):
                merged[k] = merged.get(k, 0.0) + v
            else:
                merged.setdefault(k, v)
    return merged


def _compile_costs(cfg, shape, mesh, rc):
    jitted, args = _build(cfg, shape, mesh, rc)
    with mesh:
        compiled = jitted.lower(*args).compile()
    cost = _normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": coll["collective_bytes"],
    }, coll["by_type"], op_census(hlo)


def _planner_telemetry(cfg: ModelConfig, shape: ShapeConfig,
                       rc: RunConfig) -> dict:
    """What/when/where verdict summary + sweep-cache telemetry + executed
    kernel routes for a decode cell: the serving engine consults the same
    batched planner on every ServeSession.kernel_plan build, so the
    hit/miss delta recorded here is exactly what production traffic over
    this cell's shapes would see (LRU sizing signal).  The routes block
    traces the plan-gated quantized decode step abstractly
    (serving.decode_routes) and records which projections would lower to
    the CiM INT8 Pallas path vs the standard XLA matmul."""
    from ..core.llm_workloads import gemms_of_model
    from ..core.planner import plan_workload, summarize
    from ..core.sweep import measured_cache_delta
    from ..quant import KernelPlanTable
    from ..serving import cim_fraction, decode_routes
    decisions, tel = measured_cache_delta(
        lambda: plan_workload(gemms_of_model(cfg, shape),
                              backend="vectorized"))
    table = KernelPlanTable.from_decisions(decisions,
                                           model_name=cfg.name)
    nimg = cfg.vision.n_image_tokens if cfg.family == "vlm" else 0
    routes = decode_routes(cfg, rc, table, batch=shape.global_batch,
                           max_len=shape.seq_len, n_image_tokens=nimg)
    return {"summary": summarize(decisions),
            "plan_hits": tel["plan_hits"],
            "plan_misses": tel["plan_misses"],
            "cache": tel["engine"],
            "routes": routes,
            "cim_routed_fraction": cim_fraction(routes)}


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               rc_overrides: dict | None = None,
               skip_cost_passes: bool = False):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    if not S.cell_is_runnable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "full-attention arch; long_500k requires "
                          "sub-quadratic attention (DESIGN.md §5)"}
    mesh, chips = _mesh(mesh_kind)
    rc = run_config_for(cfg, shape, rc_overrides)

    # --- official pass: the deployable scanned program -------------------
    t0 = time.time()
    jitted, args = _build(cfg, shape, mesh, rc)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes"):
            if hasattr(mem, attr):
                mem_info[attr] = int(getattr(mem, attr))

    # --- cost-extrapolation passes ----------------------------------------
    L = n_periods(cfg)
    measures, coll_types, census = [], {}, {}
    t1 = time.time()
    if not skip_cost_passes:
        for k in _unroll_points(L):
            rc_k = dataclasses.replace(rc, scan_unroll=k, microbatches=1)
            m, coll_types, census = _compile_costs(cfg, shape, mesh, rc_k)
            measures.append((k, m))
        costs = _extrapolate(measures, L)
    else:
        m, coll_types, census = _compile_costs(cfg, shape, mesh, rc)
        costs = m
    t_cost = time.time() - t1

    rf = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
        hlo_flops=costs["flops"], hlo_bytes=costs["bytes"],
        collective_bytes=costs["coll_bytes"],
        model_flops_total=model_flops(cfg, shape),
        hbm_bytes=analytic_hbm_bytes(
            cfg, shape, chips, optimizer=rc.optimizer,
            microbatches=rc.microbatches,
            kv_cache_bytes_per_el=1 if rc.kv_cache_dtype == "int8" else 2))

    res = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips,
        "run_config": {"optimizer": rc.optimizer,
                       "microbatches": rc.microbatches,
                       "kv_cache_dtype": rc.kv_cache_dtype,
                       "fsdp": rc.fsdp, **(rc_overrides or {})},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost_pass_s": round(t_cost, 1),
        "unroll_points": [k for k, _ in measures],
        "memory_analysis": mem_info,
        "cost_analysis": {"flops": costs["flops"],
                          "bytes_accessed": costs["bytes"]},
        "collectives": {"collective_bytes": costs["coll_bytes"],
                        "by_type_at_last_unroll": coll_types},
        "op_census": census,
        "roofline": rf.row(),
    }
    if shape.kind == "decode":
        res["planner"] = _planner_telemetry(cfg, shape, rc)
    return res


def all_cells():
    for arch in ARCHS:
        for shape in SHAPES:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="skip the cost-extrapolation compiles")
    ap.add_argument("--rc", default="",
                    help="JSON RunConfig overrides (perf iterations)")
    ap.add_argument("--tag", default="", help="suffix for variant runs")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    overrides = json.loads(args.rc) if args.rc else None

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = (["single", "multi"] if args.all else [args.mesh])
    for arch, shape in cells:
        for mesh_kind in meshes:
            tag = f"-{args.tag}" if args.tag else ""
            path = os.path.join(args.out,
                                f"{arch}.{shape}.{mesh_kind}{tag}.json")
            if os.path.exists(path) and not args.force:
                print(f"[skip-cached] {path}")
                continue
            print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...",
                  flush=True)
            try:
                res = lower_cell(arch, shape, mesh_kind, overrides,
                                 skip_cost_passes=args.fast)
            except Exception as e:       # record the failure, keep going
                res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            status = res["status"]
            extra = ""
            if status == "ok":
                r = res["roofline"]
                extra = (f" bottleneck={r['bottleneck']}"
                         f" frac={r['roofline_fraction']:.3f}"
                         f" compile={res['compile_s']}s"
                         f"+{res.get('cost_pass_s', 0)}s")
            print(f"[done] {arch} x {shape} x {mesh_kind}: "
                  f"{status}{extra}", flush=True)


if __name__ == "__main__":
    main()
