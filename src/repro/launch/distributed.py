"""Multi-host distributed sweeps: jax.distributed init + the global row mesh.

The sweep engine (repro.core.sweep) shards its flattened row batches over
a 1-D row mesh with `shard_map`.  Within one process that mesh spans the
process's local devices; this module extends it to **pod scale**: N
cooperating OS processes (one per host) initialize `jax.distributed`,
build ONE global row mesh over every process's devices, and evaluate each
sweep batch SPMD — every host enumerates the same grid (cheap host-side
numpy), materializes on device only the row shard its local devices own,
and all-gathers only the per-row verdict outputs (the 9 _OUT_KEYS columns
— never the intermediate cost fields, which live and die inside the
kernel).  Enumeration capacity then scales with hosts instead of one
process's RAM; combined with the engine's streaming chunk enumerator
(`SweepEngine(chunk_rows=...)`) grids larger than any single host's
memory stream through in mesh-aligned tiles.

Initialization is idempotent and env-var driven so launchers stay thin:

    REPRO_COORDINATOR=10.0.0.1:8476 REPRO_NUM_PROCESSES=8 \
    REPRO_PROCESS_ID=$RANK python my_sweep.py

    from repro.launch import distributed as dist
    dist.initialize()                    # no-op when unconfigured
    engine = dist.distributed_engine(chunk_rows=65536)

Explicit arguments always win over the env vars.  On CPU hosts the
cross-process collectives implementation (gloo) is enabled before the
backend initializes — that is what lets the multi-process parity harness
(tests/test_distributed_sweep.py) run the full distributed path on CI
containers with bitwise verdict parity against the single-process engine.

Only the final per-row outputs cross hosts: the shard_map'd kernel is a
pure data split (rows are independent, no collectives inside), so the one
communication step per chunk is the `process_allgather` of the output
columns every host needs to run the identical argmin/verdict reduction.
"""
from __future__ import annotations

import os

import jax
import numpy as np

# Env vars consumed by `initialize()` (explicit args take precedence).
ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_OUR_INIT = False      # did *this module* run jax.distributed.initialize?


def _env_int(value, var: str):
    if value is not None:
        return int(value)
    raw = os.environ.get(var)
    return int(raw) if raw else None


def is_initialized() -> bool:
    """True when this process is attached to a jax.distributed
    coordination service (whether this module or other code started it)."""
    if _OUR_INIT:
        return True
    try:
        from jax._src import distributed
        return distributed.global_state.client is not None
    except Exception:       # private API moved: fall back to our flag
        return False


def _enable_cpu_collectives() -> None:
    """Cross-process collectives on CPU backends need gloo; must be set
    before the backend initializes.  Best-effort: unknown on this jax
    (or an already-initialized backend) just means the platform default
    stands — accelerator platforms bring their own collectives."""
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass


def initialize(coordinator_address: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None,
               local_device_ids=None) -> bool:
    """Attach this process to (or skip) a multi-process jax.distributed job.

    Resolution order per field: explicit argument, then the REPRO_* env
    var.  Unconfigured (no coordinator anywhere) is the common
    single-process case and is a silent no-op; a coordinator with a
    missing process_id/num_processes is a configuration error and raises.
    Calling again after initialization is a no-op (idempotent), so
    library code may call this defensively.

    Returns True iff the process is part of a multi-process job after the
    call.
    """
    if is_initialized():
        return jax.process_count() > 1
    coordinator_address = (coordinator_address
                           or os.environ.get(ENV_COORDINATOR) or None)
    if coordinator_address is None:
        return False
    num_processes = _env_int(num_processes, ENV_NUM_PROCESSES)
    process_id = _env_int(process_id, ENV_PROCESS_ID)
    if num_processes is None or process_id is None:
        raise ValueError(
            f"distributed.initialize: coordinator {coordinator_address!r} "
            f"configured but num_processes/process_id missing (set "
            f"{ENV_NUM_PROCESSES} and {ENV_PROCESS_ID}, or pass them "
            f"explicitly)")
    _enable_cpu_collectives()
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=num_processes,
                               process_id=process_id,
                               local_device_ids=local_device_ids)
    global _OUR_INIT
    _OUR_INIT = True
    return jax.process_count() > 1


def distributed_info() -> dict:
    """Process/device topology snapshot for telemetry blocks (serve
    reports, dry-run cells, bench artifacts)."""
    return {"processes": jax.process_count(),
            "process_index": jax.process_index(),
            "global_devices": jax.device_count(),
            "local_devices": jax.local_device_count()}


def global_row_mesh(axis: str = "rows"):
    """1-D row mesh over EVERY process's devices.

    `jax.devices()` is already the global device list in a multi-process
    job, so this is launch.mesh.row_mesh over that list — the name makes
    call sites explicit about wanting the pod-spanning mesh rather than a
    local slice."""
    from .mesh import row_mesh
    return row_mesh(jax.devices(), axis=axis)


def is_multihost(mesh) -> bool:
    """Does `mesh` contain devices this process cannot address?  Such a
    mesh needs the global-array input path + output all-gather below."""
    if mesh is None:
        return False
    pi = jax.process_index()
    return any(d.process_index != pi for d in mesh.devices.flat)


def shard_balance(n_rows: int, mesh) -> dict:
    """Row counts per process for an `n_rows`-row batch split evenly over
    `mesh`'s row axis — the shard-balance telemetry serving/dry-run
    reports render (a skewed table means a host set with uneven device
    counts is bottlenecked on its largest member)."""
    per_dev, rem = divmod(n_rows, mesh.size)
    assert rem == 0, f"{n_rows} rows not aligned to {mesh.size} shards"
    counts: dict[str, int] = {}
    for d in mesh.devices.flat:
        key = str(d.process_index)
        counts[key] = counts.get(key, 0) + per_dev
    return counts


def host_local_to_global(batch: dict, mesh, axis: str | None = None) -> dict:
    """Turn replicated host (numpy) columns into row-sharded global arrays.

    Every process holds the full enumeration on host (the grid walk is
    deterministic and cheap); device memory is the scarce resource, so
    each process `device_put`s ONLY the row slices its addressable mesh
    devices own.  Row counts must already be padded to a multiple of the
    mesh size (repro.core.sweep._pad_len guarantees it).
    """
    from jax.sharding import NamedSharding, PartitionSpec
    axis = axis or mesh.axis_names[0]
    sharding = NamedSharding(mesh, PartitionSpec(axis))
    out = {}
    for name, col in batch.items():
        col = np.asarray(col)
        idx_map = sharding.addressable_devices_indices_map(col.shape)
        shards = [jax.device_put(col[idx], d) for d, idx in idx_map.items()]
        out[name] = jax.make_array_from_single_device_arrays(
            col.shape, sharding, shards)
    return out


def gather_rows(out: dict) -> dict:
    """All-gather row-sharded output columns so every host sees the full
    per-row results and runs the identical argmin/verdict reduction.

    This is the ONLY cross-host data movement of a distributed sweep —
    and it carries just the final per-row outputs (sweep._OUT_KEYS), never
    the intermediate cost fields, which stay fused inside the kernel.
    """
    from jax.experimental import multihost_utils
    gathered = multihost_utils.process_allgather(dict(out), tiled=True)
    return {k: np.asarray(v) for k, v in gathered.items()}


def distributed_engine(chunk_rows: int | None = None,
                       cache_size: int = 16384):
    """A SweepEngine over the global row mesh: the pod-scale entry point.

        dist.initialize()
        engine = dist.distributed_engine(chunk_rows=65536)
        decisions = plan_workload_batched(gemms, engine=engine)

    Every cooperating process must run the same plan queries in the same
    order (SPMD) — `plan_workload_batched` is deterministic, so that falls
    out for free.  chunk_rows bounds device memory per evaluation: grids
    bigger than one host stream through in mesh-aligned tiles (see
    SweepEngine docs).
    """
    from ..core.sweep import SweepEngine
    return SweepEngine(cache_size=cache_size, mesh=global_row_mesh(),
                       chunk_rows=chunk_rows)
