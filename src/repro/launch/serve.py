"""Serving CLI: batched generation with KV caches (deliverable b).

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --smoke --batch 4 --prompt-len 16 --new-tokens 32

--quantize runs the planner-gated INT8 session (verdicts routed into the
jitted decode step) and prints the per-label route report plus
gated-vs-ungated decode tokens/s.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, RunConfig, reduced
from ..models import init
from ..serving import CIM_ROUTE, ServeSession, cim_fraction
from ..serving.engine import _token_struct


def steady_decode_tokens_per_s(sessions, prompt, n_tokens: int,
                               repeats: int = 3) -> list[float]:
    """Steady-state decode throughput per session, best of `repeats`.

    Each session's prefill warms its one jitted executable and fills the
    cache, so every timed token is a pure decode step — first-run jit
    compile never pollutes the number (gated and ungated programs
    compile differently, so timing generate() cold would mostly compare
    compilers).  Samples ALTERNATE across the sessions so transient
    machine contention degrades all of them symmetrically: timing
    back-to-back once recorded a 2.7x split between two byte-identical
    programs."""
    if n_tokens < 1:
        raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
    for s in sessions:
        s.reset()
        s.prefill(prompt)
    cfg = sessions[0].cfg
    tok = jnp.zeros(_token_struct(cfg, prompt.shape[0]).shape, jnp.int32)

    def sample(s):
        t0 = time.perf_counter()
        for _ in range(n_tokens):
            logits, s.cache = s._step(s.params, s.cache, tok,
                                      jnp.int32(s.pos))
        jax.block_until_ready(logits)
        return time.perf_counter() - t0

    best = [float("inf")] * len(sessions)
    for _ in range(repeats):
        for i, s in enumerate(sessions):
            best[i] = min(best[i], sample(s))
    return [prompt.shape[0] * n_tokens / b for b in best]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-cache-dtype", default="bfloat16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quantize", action="store_true",
                    help="INT8 weights + planner-gated kernel routing "
                         "inside the jitted decode step")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    rc = RunConfig(attn_impl="naive", remat=False,
                   kv_cache_dtype=args.kv_cache_dtype)
    key = jax.random.PRNGKey(args.seed)
    params = init(key, cfg)
    nimg = cfg.vision.n_image_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + args.new_tokens + 1
    sess = ServeSession(cfg, rc, params, max_len=max_len,
                        batch=args.batch, n_image_tokens=nimg,
                        quantize=args.quantize)
    if cfg.family == "audio":
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len, cfg.audio.n_codebooks),
            0, cfg.vocab)
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = sess.generate(prompt, n_new=args.new_tokens,
                        temperature=args.temperature, seed=args.seed)
    dt = time.perf_counter() - t0
    plan = sess.kernel_plan
    report = {
        "arch": cfg.name, "generated_shape": list(out.shape),
        "tokens_per_s": args.batch * args.new_tokens / dt,
        "sample_row": [int(x) for x in
                       jax.device_get(out[0]).reshape(-1)[:16]],
        # what/when/where gates + planner-cache hit/miss telemetry (LRU
        # sizing is driven by these counters under production traffic).
        # The engine block inside carries the streaming-chunk accounting
        # and, on a multi-host mesh, the per-process shard balance.
        "kernel_plan": {lab: bool(d.use_cim) for lab, d in plan.items()},
        "planner_cache": sess.plan_cache_telemetry,
    }
    if jax.process_count() > 1:
        # pod-scale run: record which host printed this report and the
        # process topology next to the per-host cache counters above
        from . import distributed as dist
        report["distributed"] = dist.distributed_info()
    if args.quantize:
        # per-label executed routes + gated-vs-ungated decode throughput:
        # the ungated session keeps the same INT8 weights, so the
        # steady-state delta is purely the verdict-driven kernel routing
        # (both sessions are warmed; jit compile is excluded)
        routes = sess.route_report()
        ungated = ServeSession(cfg, rc, params, max_len=max_len,
                               batch=args.batch, n_image_tokens=nimg,
                               quantize=True, gated=False)
        tps_g, tps_u = steady_decode_tokens_per_s(
            (sess, ungated), prompt, args.new_tokens)
        report["gating"] = {
            "routes": routes,
            "cim_routed": sum(r["route"] == CIM_ROUTE
                              for r in routes.values()),
            "cim_routed_fraction": cim_fraction(routes),
            "tokens_per_s_gated": tps_g,
            "tokens_per_s_ungated": tps_u,
        }
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
