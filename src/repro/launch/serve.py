"""Serving CLI: batched generation with KV caches (deliverable b).

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --smoke --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from ..configs import ARCHS, RunConfig, reduced
from ..models import init
from ..serving import ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-cache-dtype", default="bfloat16")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    rc = RunConfig(attn_impl="naive", remat=False,
                   kv_cache_dtype=args.kv_cache_dtype)
    key = jax.random.PRNGKey(args.seed)
    params = init(key, cfg)
    nimg = cfg.vision.n_image_tokens if cfg.family == "vlm" else 0
    sess = ServeSession(cfg, rc, params,
                        max_len=args.prompt_len + args.new_tokens + 1,
                        batch=args.batch, n_image_tokens=nimg)
    if cfg.family == "audio":
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len, cfg.audio.n_codebooks),
            0, cfg.vocab)
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = sess.generate(prompt, n_new=args.new_tokens,
                        temperature=args.temperature, seed=args.seed)
    dt = time.perf_counter() - t0
    plan = sess.kernel_plan
    print(json.dumps({
        "arch": cfg.name, "generated_shape": list(out.shape),
        "tokens_per_s": args.batch * args.new_tokens / dt,
        "sample_row": [int(x) for x in
                       jax.device_get(out[0]).reshape(-1)[:16]],
        # what/when/where gates + planner-cache hit/miss telemetry (LRU
        # sizing is driven by these counters under production traffic)
        "kernel_plan": {lab: bool(d.use_cim) for lab, d in plan.items()},
        "planner_cache": sess.plan_cache_telemetry,
    }, indent=1))


if __name__ == "__main__":
    main()
