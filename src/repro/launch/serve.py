"""Serving CLI: batched generation with KV caches (deliverable b).

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --smoke --batch 4 --prompt-len 16 --new-tokens 32

--quantize runs the planner-gated INT8 session (verdicts routed into the
jitted decode step) and prints the per-label route report plus
gated-vs-ungated decode tokens/s.

--requests N switches to the continuous-batching traffic mode: N
synthetic ragged requests (seeded by --seed, so runs are reproducible)
arrive as an open-loop Poisson process at --arrival-rate req/s and are
served by the slot-scheduled, paged-KV request engine
(repro.serving.ContinuousBatchingEngine); the report carries per-request
TTFT / queue wait / tokens/s plus engine-level queue depth, slot
occupancy, KV-block usage and eviction counts.  All defaults are
documented in --help.

--adaptive (traffic mode, implies --quantize) puts the shape-bucketed
plan service (repro.core.plan_service) beside the engine: every step the
live (active slots, max position) point is bucketed, the bucket's
verdicts are served from the sweep LRU, and a verdict change hot-swaps
the decode plan between compiled variants.  --bucket-edges overrides the
lattice ("b1,b2,..:l1,l2,.."); --refresh-every N re-plans a bucket in
the background after every N lookups.  The report gains the engine's
`adaptive` telemetry block (bucket hit rates, flips, swap latency),
rendered by launch.report.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs import ARCHS, RunConfig, reduced
from ..models import init
from ..serving import (CIM_ROUTE, ContinuousBatchingEngine, DecodeCore,
                       ServeSession, cim_fraction, poisson_arrivals,
                       synthetic_requests)
from ..serving.engine import _token_struct


def steady_decode_tokens_per_s(sessions, prompt, n_tokens: int,
                               repeats: int = 3,
                               warmup: int = 0) -> list[float]:
    """Steady-state decode throughput per session, best of `repeats`
    timed samples of `n_tokens` decode steps each.

    Each session's prefill warms its one jitted executable and fills the
    cache, so every timed token is a pure decode step — first-run jit
    compile never pollutes the number (gated and ungated programs
    compile differently, so timing generate() cold would mostly compare
    compilers).  `warmup` extra *untimed* decode steps per session after
    prefill soak residual first-call overhead (allocator warm-up, dtype
    promotion caches) for callers that want even flatter samples.
    Samples ALTERNATE across the sessions so transient machine
    contention degrades all of them symmetrically: timing back-to-back
    once recorded a 2.7x split between two byte-identical programs.

    The single timing loop shared by the gating benchmark and the
    traffic benchmark's fixed-batch reference row — tune via their
    --new-tokens/--repeats/--warmup flags."""
    if n_tokens < 1:
        raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    for s in sessions:
        s.reset()
        s.prefill(prompt)
    cfg = sessions[0].cfg
    tok = jnp.zeros(_token_struct(cfg, prompt.shape[0]).shape, jnp.int32)

    def sample(s, n):
        t0 = time.perf_counter()
        for _ in range(n):
            logits, s.cache = s._step(s.params, s.cache, tok,
                                      jnp.int32(s.pos))
        jax.block_until_ready(logits)
        return time.perf_counter() - t0

    if warmup:
        for s in sessions:
            sample(s, warmup)
    best = [float("inf")] * len(sessions)
    for _ in range(repeats):
        for i, s in enumerate(sessions):
            best[i] = min(best[i], sample(s, n_tokens))
    return [prompt.shape[0] * n_tokens / b for b in best]


def run_traffic(cfg, rc, params, args) -> dict:
    """Continuous-batching traffic mode: synthetic open-loop arrivals
    through the slot-scheduled paged-KV engine (optionally with the
    shape-bucketed adaptive plan service); returns the serve report
    dict."""
    from ..core.plan_service import BucketLattice, PlanService
    quantize = args.quantize or args.adaptive
    max_len = args.max_len or (args.prompt_len + args.new_tokens + 1)
    core = DecodeCore(cfg, rc, params, quantize=quantize,
                      plan_batch=args.slots, plan_max_len=max_len)
    service = None
    if args.adaptive:
        lattice = (BucketLattice.parse(args.bucket_edges)
                   if args.bucket_edges
                   else BucketLattice.for_engine(args.slots, max_len))
        service = PlanService(cfg, lattice,
                              refresh_every=args.refresh_every)
    engine = ContinuousBatchingEngine(
        core, n_slots=args.slots, max_len=max_len,
        block_size=args.block_size, n_kv_blocks=args.kv_blocks,
        seed=args.seed, plan_service=service)
    reqs = synthetic_requests(
        cfg, args.requests, seed=args.seed,
        prompt_len=(max(1, args.prompt_len // 2), args.prompt_len),
        new_tokens=(max(1, args.new_tokens // 2), args.new_tokens),
        temperature=args.temperature)
    arrivals = poisson_arrivals(args.requests, args.arrival_rate,
                                seed=args.seed)
    telemetry = engine.run(reqs, arrivals)
    if service is not None:
        service.drain()              # settle background refreshes
        telemetry["adaptive"] = engine._adaptive_telemetry()
    report = {
        "arch": cfg.name,
        "mode": "continuous-batching",
        "requests": args.requests,
        "arrival_rate_req_per_s": args.arrival_rate,
        "seed": args.seed,
        "adaptive": args.adaptive,
        "traffic": telemetry,
        "planner_cache": core.plan_cache_telemetry,
    }
    if quantize:
        routes = core.route_report(args.slots, engine.max_len)
        report["gating"] = {
            "routes": routes,
            "cim_routed": sum(r["route"] == CIM_ROUTE
                              for r in routes.values()),
            "cim_routed_fraction": cim_fraction(routes),
        }
    return report


def main():
    ap = argparse.ArgumentParser(
        description="Serve a model: fixed-batch demo (default) or "
                    "continuous-batching synthetic traffic "
                    "(--requests N).",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--arch", default="mistral-nemo-12b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="prompt length (traffic mode: the max of the "
                         "ragged range [prompt-len/2, prompt-len])")
    ap.add_argument("--new-tokens", type=int, default=32,
                    help="tokens to generate (traffic mode: the max of "
                         "the ragged range [new-tokens/2, new-tokens])")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-cache-dtype", default="bfloat16")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds weights AND the synthetic traffic "
                         "(request shapes, arrival process, sampling) — "
                         "same seed, same run")
    ap.add_argument("--quantize", action="store_true",
                    help="INT8 weights + planner-gated kernel routing "
                         "inside the jitted decode step")
    # --- continuous-batching traffic mode ---
    ap.add_argument("--requests", type=int, default=0,
                    help="synthetic traffic mode: number of requests to "
                         "serve through the continuous-batching engine "
                         "(0 = legacy fixed-batch demo)")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="open-loop Poisson arrival rate in requests/s "
                         "(0 = all requests arrive at t=0)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (the fixed jitted batch size the "
                         "scheduler packs requests into)")
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged-KV block size in tokens")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="KV pool capacity in blocks (default: full "
                         "provisioning, slots * ceil(max-len/block-"
                         "size))")
    ap.add_argument("--max-len", type=int, default=0,
                    help="per-request length cap in traffic mode "
                         "(0 = prompt-len + new-tokens + 1)")
    ap.add_argument("--adaptive", action="store_true",
                    help="traffic mode: consult the shape-bucketed plan "
                         "service each step and hot-swap the decode plan "
                         "on verdict flips (implies --quantize)")
    ap.add_argument("--bucket-edges", default="",
                    help="adaptive bucket lattice as 'b1,b2,..:l1,l2,..' "
                         "(batch edges : length edges; empty = power-of-"
                         "two edges over slots x max-len)")
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="adaptive: background re-plan a bucket after "
                         "every N lookups (0 = never refresh)")
    args = ap.parse_args()
    if args.adaptive and args.requests <= 0:
        ap.error("--adaptive needs traffic mode (--requests N)")

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    rc = RunConfig(attn_impl="naive", remat=False,
                   kv_cache_dtype=args.kv_cache_dtype)
    key = jax.random.PRNGKey(args.seed)
    params = init(key, cfg)
    if args.requests > 0:
        print(json.dumps(run_traffic(cfg, rc, params, args), indent=1))
        return
    nimg = cfg.vision.n_image_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + args.new_tokens + 1
    sess = ServeSession(cfg, rc, params, max_len=max_len,
                        batch=args.batch, n_image_tokens=nimg,
                        quantize=args.quantize)
    if cfg.family == "audio":
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len, cfg.audio.n_codebooks),
            0, cfg.vocab)
    else:
        prompt = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.perf_counter()
    out = sess.generate(prompt, n_new=args.new_tokens,
                        temperature=args.temperature, seed=args.seed)
    dt = time.perf_counter() - t0
    plan = sess.kernel_plan
    report = {
        "arch": cfg.name, "generated_shape": list(out.shape),
        "tokens_per_s": args.batch * args.new_tokens / dt,
        "sample_row": [int(x) for x in
                       jax.device_get(out[0]).reshape(-1)[:16]],
        # what/when/where gates + planner-cache hit/miss telemetry (LRU
        # sizing is driven by these counters under production traffic).
        # The engine block inside carries the streaming-chunk accounting
        # and, on a multi-host mesh, the per-process shard balance.
        "kernel_plan": {lab: bool(d.use_cim) for lab, d in plan.items()},
        "planner_cache": sess.plan_cache_telemetry,
    }
    if jax.process_count() > 1:
        # pod-scale run: record which host printed this report and the
        # process topology next to the per-host cache counters above
        from . import distributed as dist
        report["distributed"] = dist.distributed_info()
    if args.quantize:
        # per-label executed routes + gated-vs-ungated decode throughput:
        # the ungated session keeps the same INT8 weights, so the
        # steady-state delta is purely the verdict-driven kernel routing
        # (both sessions are warmed; jit compile is excluded)
        routes = sess.route_report()
        ungated = ServeSession(cfg, rc, params, max_len=max_len,
                               batch=args.batch, n_image_tokens=nimg,
                               quantize=True, gated=False)
        tps_g, tps_u = steady_decode_tokens_per_s(
            (sess, ungated), prompt, args.new_tokens)
        report["gating"] = {
            "routes": routes,
            "cim_routed": sum(r["route"] == CIM_ROUTE
                              for r in routes.values()),
            "cim_routed_fraction": cim_fraction(routes),
            "tokens_per_s_gated": tps_g,
            "tokens_per_s_ungated": tps_u,
        }
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
