"""Training CLI (end-to-end driver, deliverable b).

  PYTHONPATH=src python -m repro.launch.train \
      --arch qwen2-7b --smoke --steps 200 --ckpt-dir /tmp/ckpt

--smoke trains the reduced config on CPU (the ~100M-class run); the full
configs are for real TPU slices (the multi-pod dry-run proves lowering).
"""
from __future__ import annotations

import argparse
import json

import jax

from ..configs import ARCHS, RunConfig, reduced
from ..data import DataConfig
from ..train import train
from ..train.fault_tolerance import FailureInjector


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a failure at this step (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    rc = RunConfig(optimizer=args.optimizer, learning_rate=args.lr,
                   microbatches=args.microbatches, remat=False,
                   attn_impl="naive", warmup_steps=max(1, args.steps // 10))
    dc = DataConfig(seed=args.seed, vocab=cfg.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    injector = (FailureInjector(fail_at_steps=(args.fail_at,))
                if args.fail_at else None)
    res = train(cfg, rc, dc, n_steps=args.steps, seed=args.seed,
                ckpt_dir=args.ckpt_dir or None,
                ckpt_every=args.ckpt_every, injector=injector)
    print(json.dumps({
        "arch": cfg.name, "steps": args.steps,
        "resumed_from": res.resumed_from,
        "loss_first": res.losses[0], "loss_last": res.losses[-1],
        "stragglers": res.straggler_steps,
        "devices": len(jax.devices()),
    }, indent=1))


if __name__ == "__main__":
    main()
