"""Production mesh construction (assignment deliverable e).

make_production_mesh is a FUNCTION — importing this module never touches
jax device state.  Single pod: (data=16, model=16) over 256 chips.
Multi-pod: (pod=2, data=16, model=16) over 512 chips; the `pod` axis is a
second data-parallel axis crossing the slower inter-pod links (gradient
all-reduce over it can be int8-compressed, optim.grad_compress).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def abstract_mesh(shape, axes):
    """Device-less mesh for sharding-spec legality checks.

    jax <= 0.4.x takes AbstractMesh(((name, size), ...)); newer releases
    take AbstractMesh(shape, axis_names).  Normalize here so callers (and
    tests) work on either.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_mesh_from_devices(devices, shape, axes):
    """Mesh over an explicit device subset (elastic re-mesh after node
    loss, or the single-pod 256-of-512 slice in the dry-run)."""
    arr = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def single_pod_mesh_from(devices):
    """16x16 (data, model) mesh from the first 256 of the given devices."""
    return make_mesh_from_devices(list(devices)[:256], (16, 16),
                                  ("data", "model"))


def row_mesh(devices=None, axis: str = "rows"):
    """1-D mesh over `devices` (default: all) for row-sharded batch
    evaluation — the sweep engine splits its flattened (GEMM, config,
    mapping) row batches over this axis (repro.core.sweep).

    `jax.devices()` is the GLOBAL device list, so in a multi-process
    jax.distributed job the default mesh already spans every host; the
    engine then routes evaluation through the multi-host path
    (launch.distributed: per-host shard materialization + output
    all-gather).  Pass `jax.local_devices()` to force a one-host mesh."""
    devices = list(devices if devices is not None else jax.devices())
    return make_mesh_from_devices(devices, (len(devices),), (axis,))


def small_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU tests (devices must already exist)."""
    devs = jax.devices()[: n_data * n_model]
    return make_mesh_from_devices(devs, (n_data, n_model),
                                  ("data", "model"))
