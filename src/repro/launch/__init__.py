"""Launch layer: production mesh, dry-run driver, roofline, train/serve CLIs.

NOTE: import repro.launch.dryrun only as __main__ (it pins
XLA_FLAGS=--xla_force_host_platform_device_count=512 at import).
"""
from . import mesh, roofline, specs  # noqa: F401  (dryrun NOT imported here)
