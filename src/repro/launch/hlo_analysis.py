"""Post-partitioning HLO analysis: collective byte counting + op census.

cost_analysis() has no collective traffic, so we parse the optimized
(SPMD-partitioned, per-device) HLO text and sum the result-shape bytes of
every collective op.  Ring all-reduce moves ~2x its payload per device;
other collectives ~1x — the returned `collective_bytes` applies those
factors (a consistent, iteration-comparable metric; see EXPERIMENTS.md).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# e.g.:  %ar = f32[16,4096]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"(?:^|\s)(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\(?[a-z0-9]+\[[0-9,]*\][^ ]*\)?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[\s(.]")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                   "reduce-scatter": 1.0, "all-to-all": 1.0,
                   "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Returns {"collective_bytes": float, "by_type": {op: {count, bytes}}}.

    `collective_bytes` = sum over ops of result bytes x traffic factor —
    the per-device payload crossing links.
    """
    by_type: dict = defaultdict(lambda: {"count": 0, "bytes": 0.0})
    total = 0.0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        # skip token/control-only collectives
        b = _shape_bytes(shape_str)
        if op.endswith("-start"):
            op = op[:-6]
        by_type[op]["count"] += 1
        by_type[op]["bytes"] += b
        total += b * _TRAFFIC_FACTOR[op]
    return {"collective_bytes": total,
            "by_type": {k: dict(v) for k, v in by_type.items()}}


def op_census(hlo_text: str, ops=("fusion", "dot", "convolution",
                                  "transpose", "reshape", "copy")) -> dict:
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"= [^=]*\b{op}\(", hlo_text))
    return out
