"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell —
weak-type-correct, shardable, zero allocation (assignment deliverable e.2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import LONG_500K, ModelConfig, RunConfig, ShapeConfig
from ..models import init as model_init
from ..models import init_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, l = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        tok = sds((b, l, cfg.audio.n_codebooks), jnp.int32)
    else:
        tok = sds((b, l), jnp.int32)
    specs = {"tokens": tok, "targets": tok}
    if cfg.family == "vlm":
        specs["image_embeds"] = sds(
            (b, cfg.vision.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, l = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        tok = sds((b, l, cfg.audio.n_codebooks), jnp.int32)
    else:
        tok = sds((b, l), jnp.int32)
    specs = {"tokens": tok}
    if cfg.family == "vlm":
        specs["image_embeds"] = sds(
            (b, cfg.vision.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return specs


def decode_input_specs(cfg: ModelConfig, rc: RunConfig,
                       shape: ShapeConfig) -> dict:
    """Token + KV-cache stand-ins for one serve_step (cache depth =
    shape.seq_len, one new token)."""
    b = shape.global_batch
    nimg = cfg.vision.n_image_tokens if cfg.family == "vlm" else 0
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg, rc, b, shape.seq_len,
                          n_image_tokens=nimg))
    if cfg.family == "audio":
        tok = sds((b, 1, cfg.audio.n_codebooks), jnp.int32)
    else:
        tok = sds((b, 1), jnp.int32)
    return {"cache": cache, "tokens": tok,
            "pos": sds((), jnp.int32)}


def param_shapes(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)   # PRNG key stand-in
    return jax.eval_shape(
        functools.partial(model_init, cfg=cfg), jax.random.PRNGKey(0))


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic attention (assignment note)."""
    if shape.name == LONG_500K.name:
        return cfg.sub_quadratic
    return True
