"""Design-space campaign CLI: stream a grid, emit the frontier.

  PYTHONPATH=src python -m repro.launch.campaign --out results/campaign

The default grid is the full production campaign — every arch x shape
cell of the config registry crossed with the four Table-IV prototypes,
three cache levels, five primitive-budget scales, both input-driver
serialization modes (RF only), two K:N balance thresholds, and both
DRAM order modes: 140k+ points, streamed through the chunked sweep
engine in bounded blocks (peak memory is O(block + chunk + front), not
O(grid)).  Outputs land in --out:

  frontier.csv         the Pareto fronts, canonical order, sha256-pinned
  campaign_report.json provenance (git sha, grid digest), run stats,
                       constraint accounting, and the certification
                       gate's verdicts for each group's champion row

Constraint contracts are repeatable `--constraint metric<=bound` flags
(metrics: energy_pj, time_ns, area_bytes, gflops, tops_per_w), applied
before front reduction and re-asserted by certification.  Use
--dry-run to print the grid spec (including point count and digest)
without evaluating anything.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import subprocess
import sys
import time
from datetime import datetime, timezone

import jax

from ..configs import ARCHS, SHAPES
from ..core.campaign import (CIM_LEVELS, CampaignSpec, Constraint,
                             certify_front, run_campaign)
from ..core.sweep import CIM_BACKENDS, SweepEngine

DEFAULT_SCALES = (0.5, 1.0, 2.0, 4.0, 8.0)


def provenance() -> dict:
    try:
        # --dirty marks artifacts produced by uncommitted code: the bare
        # sha alone would claim a commit that cannot reproduce the run
        sha = subprocess.check_output(
            ["git", "describe", "--always", "--dirty"], text=True,
            stderr=subprocess.DEVNULL).strip()
    except Exception:
        sha = "unknown"
    return {"git_sha": sha,
            "host": socket.gethostname(),
            "timestamp_utc": datetime.now(timezone.utc)
            .strftime("%Y-%m-%dT%H:%M:%SZ"),
            "jax": jax.__version__,
            "device": jax.devices()[0].platform}


def default_workloads() -> tuple[tuple[str, str], ...]:
    """Every arch x shape cell in the registry, registry order."""
    return tuple((a, s) for a in ARCHS for s in SHAPES)


def parse_workloads(items: list[str]) -> tuple[tuple[str, str], ...]:
    out = []
    for item in items:
        arch, sep, shape = item.partition("/")
        if not sep:
            raise SystemExit(f"bad --workload {item!r}: expected "
                             f"'arch/shape'")
        out.append((arch, shape))
    return tuple(out)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        description="Streaming design-space campaign: Pareto frontiers "
                    "over (energy, latency, area) with constraint "
                    "contracts and a certification gate.",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    p.add_argument("--workload", action="append", default=None,
                   metavar="ARCH/SHAPE",
                   help="workload cell (repeatable); default: every "
                        "arch x shape cell in the registry")
    p.add_argument("--prototypes", nargs="+",
                   default=["Analog-6T", "Analog-8T", "Digital-6T",
                            "Digital-8T"])
    p.add_argument("--levels", nargs="+", default=list(CIM_LEVELS),
                   choices=list(CIM_LEVELS))
    p.add_argument("--scales", nargs="+", type=float,
                   default=list(DEFAULT_SCALES),
                   help="primitive-budget scales vs the level's "
                        "iso-area count")
    p.add_argument("--serialize", choices=["ser", "par", "both"],
                   default="both",
                   help="input-driver serialization modes (RF only; "
                        "a no-op at SMEM)")
    p.add_argument("--kn-thresholds", nargs="+", type=int,
                   default=[4, 8],
                   help="mapping K:N balance thresholds")
    p.add_argument("--order-modes", nargs="+",
                   default=["exact", "greedy"],
                   choices=["exact", "greedy", "fixed"])
    p.add_argument("--precisions", nargs="+", type=int, default=[8],
                   help="GEMM bit widths (cost model calibrated at 8)")
    p.add_argument("--constraint", action="append", default=[],
                   metavar="METRIC<=BOUND",
                   help="constraint contract, repeatable (e.g. "
                        "'time_ns<=2e9', 'area_bytes<=1e5')")
    p.add_argument("--backend", choices=list(CIM_BACKENDS),
                   default="vectorized")
    p.add_argument("--group-by", choices=["workload", "gemm"],
                   default="workload")
    p.add_argument("--block-points", type=int, default=4096,
                   help="points buffered per engine call")
    p.add_argument("--chunk-rows", type=int, default=4096,
                   help="sweep-engine device chunk size")
    p.add_argument("--certify-objectives", nargs="+",
                   default=["energy_pj"],
                   help="certify each group's champion per objective")
    p.add_argument("--max-certify-groups", type=int, default=None,
                   help="cap certified groups (default: all)")
    p.add_argument("--out", default="results/campaign")
    p.add_argument("--dry-run", action="store_true",
                   help="print the grid spec and exit without "
                        "evaluating")
    return p


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    workloads = (parse_workloads(args.workload) if args.workload
                 else default_workloads())
    serialize_modes = {"ser": (True,), "par": (False,),
                       "both": (True, False)}[args.serialize]
    spec = CampaignSpec(
        workloads=workloads,
        prototypes=tuple(args.prototypes),
        levels=tuple(args.levels),
        scales=tuple(args.scales),
        serialize_modes=serialize_modes,
        kn_thresholds=tuple(args.kn_thresholds),
        order_modes=tuple(args.order_modes),
        precisions=tuple(args.precisions),
    )
    contracts = tuple(Constraint.parse(c) for c in args.constraint)

    print(f"[campaign] grid: {spec.n_points} points "
          f"({len(workloads)} workload cells x {spec.n_units} units), "
          f"digest {spec.digest()}", flush=True)
    if args.dry_run:
        print(json.dumps(spec.describe(), indent=1))
        return 0

    engine = SweepEngine(chunk_rows=args.chunk_rows)
    t0 = time.perf_counter()
    result = run_campaign(spec, contracts, engine=engine,
                          backend=args.backend,
                          block_points=args.block_points,
                          group_by=args.group_by)
    run_s = time.perf_counter() - t0
    print(f"[campaign] evaluated in {run_s:.1f}s — "
          f"{len(result.front)} front rows across "
          f"{result.stats['n_groups']} groups, "
          f"{result.stats['engine_chunks']['evaluated']} engine chunks",
          flush=True)

    t0 = time.perf_counter()
    cert = certify_front(result, objectives=args.certify_objectives,
                         max_groups=args.max_certify_groups)
    cert_s = time.perf_counter() - t0
    status = "OK" if cert["ok"] else "FAILED"
    print(f"[campaign] certification {status}: "
          f"{len(cert['points'])} champion points re-evaluated "
          f"in {cert_s:.1f}s", flush=True)

    os.makedirs(args.out, exist_ok=True)
    csv_path = os.path.join(args.out, "frontier.csv")
    sha = result.write_csv(csv_path)
    report = {
        "provenance": provenance(),
        "frontier_csv": {"path": csv_path, "sha256": sha,
                         "rows": len(result.front)},
        "run_seconds": round(run_s, 2),
        "certify_seconds": round(cert_s, 2),
        "report": result.report(),
        "certification": cert,
    }
    report_path = os.path.join(args.out, "campaign_report.json")
    with open(report_path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"[campaign] wrote {csv_path} (sha256 {sha[:16]}) "
          f"and {report_path}", flush=True)
    return 0 if cert["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
