"""Three-term roofline from a compiled dry-run artifact (deliverable g).

  compute    = HLO_FLOPs_per_device / 197e12          (bf16 peak / chip)
  memory     = HLO_bytes_per_device / 819e9           (HBM bandwidth)
  collective = collective_bytes_per_device / 50e9     (per-link ICI)

The compiled module is the per-device SPMD program, so cost_analysis() is
already per-chip.  MODEL_FLOPS uses 6·N·D (train) / 2·N_active·tokens +
attention (serve), divided by chip count — the "useful fraction" of the
compiled FLOPs catches remat/dispatch waste.
"""
from __future__ import annotations

import dataclasses

from ..configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # per device
    hlo_bytes: float             # per device (XLA bytes-accessed)
    collective_bytes: float      # per device
    model_flops_total: float     # whole step, all devices
    hbm_bytes: float = 0.0       # per device, analytic (fusion-adjusted)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s_xla(self) -> float:
        """XLA bytes-accessed / HBM bw.  Every op's operands counted — a
        gross HBM upper bound on the unfused CPU backend; reported for the
        spec, not used for the bottleneck verdict."""
        return self.hlo_bytes / HBM_BW

    @property
    def memory_s(self) -> float:
        """Analytic HBM traffic (params/grads/optstate/activations/cache,
        post-fusion) / HBM bw — the memory term used for the bottleneck."""
        return (self.hbm_bytes or self.hlo_bytes) / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        dev_model = self.model_flops_total / max(1, self.chips)
        return dev_model / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step achieves at the roofline
        bound = useful-FLOPs time / bound time (the §Perf score)."""
        dev_model = self.model_flops_total / max(1, self.chips)
        ideal = dev_model / PEAK_FLOPS
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "memory_s_xla": self.memory_s_xla,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "hlo_flops_dev": self.hlo_flops,
            "hbm_bytes_dev": self.hbm_bytes,
            "useful_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic useful FLOPs for one step of this (arch, shape) cell."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn = _attn_flops(cfg, shape.seq_len, shape.global_batch,
                           causal=True) * 3.0      # fwd + bwd(2x)
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens + _attn_flops(
            cfg, shape.seq_len, shape.global_batch, causal=True)
    # decode: one token against a seq_len cache
    b = shape.global_batch
    base = 2.0 * n_active * b
    attn = _decode_attn_flops(cfg, shape.seq_len, b)
    return base + attn


def _n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def _attn_flops(cfg: ModelConfig, s: int, b: int, causal: bool) -> float:
    n = _n_attn_layers(cfg)
    if n == 0:
        return 0.0
    h, dh = cfg.n_heads, cfg.head_dim()
    eff = min(s, cfg.sliding_window) if cfg.sliding_window else s
    per_layer = 2.0 * b * h * s * eff * dh * (0.5 if causal
                                              and not cfg.sliding_window
                                              else 1.0) * 2  # QK^T + PV
    return n * per_layer


def _decode_attn_flops(cfg: ModelConfig, s_cache: int, b: int) -> float:
    n = _n_attn_layers(cfg)
    if n == 0:
        return 0.0
    h, dh = cfg.n_heads, cfg.head_dim()
    eff = min(s_cache, cfg.sliding_window) if cfg.sliding_window else s_cache
    return n * 4.0 * b * h * eff * dh


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                       optimizer: str = "adamw", microbatches: int = 1,
                       kv_cache_bytes_per_el: int = 2,
                       tp: int = 16) -> float:
    """Per-device HBM traffic per step, assuming TPU-grade fusion.

    Train: weights read fwd+bwd at the TP shard size (FSDP gathers land in
    HBM once per layer per pass), grads written + read, optimizer state
    read+written, remat-saved layer inputs written+read, logits in fp32.
    Decode: full local weight + cache read, cache line write.
    Prefill: local weights + activations.
    """
    P = cfg.param_count()
    P_active = cfg.active_param_count()
    d, V = cfg.d_model, cfg.vocab
    if shape.kind == "train":
        tokens_local = shape.seq_len * shape.global_batch / max(1, chips // tp)
        w = 2.0 * 2 * P / tp               # bf16 weights, fwd + bwd passes
        g = 2.0 * 2 * P / chips            # grad write + read (shard, f32->bf16ish)
        if optimizer == "adamw":
            opt = (4 + 4) * 2.0 * P / chips    # m,v f32 read+write
        else:
            opt = 0.2 * P / chips              # factored state
        upd = 2 * 2.0 * P / chips
        acts = 2.0 * tokens_local * d * 2 * cfg.n_layers / microbatches \
            * microbatches        # saved carries written + read (per mb)
        logits = tokens_local * V * 4.0 / tp
        return w + g + opt + upd + acts + logits
    if shape.kind == "prefill":
        tokens_local = shape.seq_len * shape.global_batch \
            / max(1, chips // tp)
        w = 2.0 * P_active / tp
        acts = 2.0 * tokens_local * d * 2 * cfg.n_layers
        return w + acts
    # decode
    w = 2.0 * P_active / tp
    n_attn = _n_attn_layers(cfg)
    cache = (2.0 * n_attn * shape.global_batch * shape.seq_len
             * cfg.n_kv_heads * cfg.head_dim()
             * kv_cache_bytes_per_el) / chips
    return w + cache
