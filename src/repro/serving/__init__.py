"""Serving: prefill + batched KV-cache decode, planner-gated execution."""
from .engine import (CIM_ROUTE, ServeSession, cim_fraction, decode_routes,
                     make_prefill, make_serve_step)

__all__ = ["ServeSession", "make_prefill", "make_serve_step",
           "decode_routes", "cim_fraction", "CIM_ROUTE"]
