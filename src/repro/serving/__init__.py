"""Serving: prefill + batched KV-cache decode."""
from .engine import ServeSession, make_prefill, make_serve_step

__all__ = ["ServeSession", "make_prefill", "make_serve_step"]
