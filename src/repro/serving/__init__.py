"""Serving: one immutable compiled decode core (DecodeCore) under two
request layers — the legacy fixed-batch ServeSession and the
slot-scheduled, paged-KV ContinuousBatchingEngine — all planner-gated."""
from .core import DecodeCore, sample_token
from .engine import (CIM_ROUTE, ServeSession, cim_fraction, decode_routes,
                     make_prefill, make_serve_step)
from .scheduler import (BlockAllocator, ContinuousBatchingEngine, Request,
                        poisson_arrivals, synthetic_requests)

__all__ = ["ServeSession", "DecodeCore", "ContinuousBatchingEngine",
           "Request", "BlockAllocator", "make_prefill", "make_serve_step",
           "decode_routes", "cim_fraction", "sample_token",
           "synthetic_requests", "poisson_arrivals", "CIM_ROUTE"]
