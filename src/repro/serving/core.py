"""The immutable compiled core of the serving stack.

`DecodeCore` owns everything that must be frozen *before* jitting and
then never changes while requests stream through: the model/run configs,
the (optionally INT8-quantized) parameters, the What/When/Where verdicts
as a jit-static `KernelPlanTable`, and the jitted decode executables.
The scheduler layer (repro.serving.scheduler) and the legacy fixed-batch
`ServeSession` (repro.serving.engine) are both thin mutable shells over
one core — requests join and leave, the core never retraces.

Two kinds of executables live here, each compiled exactly once per plan:

  * `step(params, cache, tokens, pos)` — the legacy fixed-batch step
    (scalar uniform position), what the dry-run lowers and ServeSession
    drives;
  * `batch_step(params, cache, tokens, pos, active, block_tables)` — the
    continuous-batching step: ragged per-slot positions, an active-slot
    mask, and a paged KV block pool (models.model.init_paged_cache).
    All four scheduler-side inputs are jit-*dynamic*, so slot churn under
    live traffic hits the same compiled program every step.

The continuous-batching step is served from a **bounded per-plan
executable cache** (`batch_step_for(plan)`): each distinct (versioned)
`KernelPlanTable` gets its own jitted program, LRU-bounded at
`max_plan_variants`.  That is what lets the adaptive serving layer
(`repro.serving.scheduler` + `repro.core.plan_service`) hot-swap the
decode plan when a shape bucket's verdict flips — a flip compiles the
new variant once, off the critical decode step, and every later step
under either plan reuses its already-compiled program
(`batch_decode_executables == number of distinct plans served`).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models import decode_step
from ..models.layers import route_trace
from ..quant import (KernelPlanTable, quantize_model_params_lowbit,
                     strip_model_prefix)


def _token_struct(cfg: ModelConfig, batch: int):
    shape = (batch, 1) + ((cfg.audio.n_codebooks,)
                          if cfg.family == "audio" else ())
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def sample_token(cfg: ModelConfig, logits, temperature: float, key):
    """Greedy / temperature sampling of the next token from step logits.

    One definition shared by the fixed-batch session and the continuous
    engine, so the two paths cannot drift.  Returns tokens shaped for
    feeding back into the decode step ((b, 1), audio: (b, 1, nb))."""
    last = logits[:, -1]
    if temperature <= 0.0:
        tok = jnp.argmax(last, axis=-1)
    else:
        tok = jax.random.categorical(key, last / temperature)
    if cfg.family == "audio":
        return tok[:, None, :] if tok.ndim == 2 else tok[:, None]
    return tok[:, None].astype(jnp.int32)


@dataclasses.dataclass
class DecodeCore:
    """Frozen compiled core: params + plan + the jitted decode programs.

    quantize=True turns the planner verdicts into the execution policy:
    projection weights are INT8-quantized at init, the kernel plan is
    built eagerly (before jitting), and both jitted steps close over the
    static KernelPlanTable.  gated=False keeps the quantized weights but
    forces every label onto the standard path — the parity baseline for
    the gated program (identical numerics source, routing the only
    difference)."""
    cfg: ModelConfig
    rc: RunConfig
    params: Any
    quantize: bool = False
    gated: bool = True
    # weight precision of the quantized execution path (the What axis at
    # runtime): "int8" (default), "int4" (packed nibbles) or "fp8"
    # (e4m3 scaled) — models.layers.linear dispatches each format to its
    # own CiM-Pallas / dequant-XLA route pair
    precision: str = "int8"
    # decode shape the planner reasons about (batch is what matters for
    # the paper's M=1 pathology; ServeSession passes its own)
    plan_batch: int = 8
    plan_max_len: int = 1024
    # bound on concurrently-cached jitted batch-step variants (one per
    # distinct plan table the adaptive layer has served)
    max_plan_variants: int = 4
    # donate the cache argument of both jitted steps so XLA aliases the
    # KV pools / mamba state into the outputs (in-place update, no
    # per-token copy of the multi-MB cache).  None resolves per
    # platform: on accelerators aliasing is the point; on CPU the
    # aliased program measured ~20% SLOWER (XLA:CPU), so it defaults
    # off there.  Tests force donate=True to prove the in-place
    # semantics regardless of platform.
    donate: bool | None = None

    def __post_init__(self):
        if self.max_plan_variants < 1:
            raise ValueError(f"max_plan_variants must be >= 1, "
                             f"got {self.max_plan_variants}")
        self._kernel_plan = None
        self._kernel_plans = None
        self._plan_cache_telemetry = None
        self._plan_lock = threading.Lock()
        self._verdict_table = None
        self._phase_verdict_tables = None
        self._batch_steps: OrderedDict = OrderedDict()
        self._exec_lock = threading.Lock()
        self.plan_evictions = 0
        self.plan_table = None
        self.prefill_plan_table = None
        if self.quantize:
            # plan BEFORE jit: the verdicts are static inputs of the
            # lowered decode/prefill programs, not runtime state.  Each
            # serving phase gets its *own* table (planner
            # plan_workload_by_phase): prefill GEMMs carry M = seq_len
            # reuse, decode GEMMs collapse to M = batch, so their
            # What/When verdicts legitimately differ.
            tables = self.phase_verdict_tables
            table, ptable = tables["decode"], tables["prefill"]
            self.plan_table = table if self.gated else table.ungated()
            pgate = ptable if self.gated else ptable.ungated()
            # when the phases gate every *projection* identically, the
            # lowered programs would be identical — alias the execution
            # table so the phases share ONE compiled step.  Activation
            # GEMMs (QK^T / pV scores) have no stationary weight and
            # never consult the table, so their phase-specific labels
            # must not force a redundant second program.
            from ..core.llm_workloads import is_projection_label
            proj_flips = [lab for lab in self.plan_table.flips(pgate)
                          if is_projection_label(lab)]
            self.prefill_plan_table = (pgate if proj_flips
                                       else self.plan_table)
            self.params = quantize_model_params_lowbit(self.params,
                                                       self.precision)
        if self.donate is None:
            self.donate = jax.default_backend() != "cpu"
        cfg, rc, plan = self.cfg, self.rc, self.plan_table
        # when donating, the cache argument is consumed: XLA aliases the
        # input KV pools / mamba state to the output and updates them in
        # place instead of copying the multi-MB cache every token.
        # Callers must rebind (`logits, cache = step(params, cache,
        # ...)`) and never touch the donated input again — every in-repo
        # caller does.
        self._step = jax.jit(
            lambda params, cache, tokens, pos:
            decode_step(params, cache, tokens, pos, cfg, rc, plan=plan),
            donate_argnums=(1,) if self.donate else ())
        # the prefill-phase step: same per-token decode fn closed over
        # the prefill table.  When the phases agree (or the core is
        # unquantized/ungated: both plans identical) the decode program
        # is shared — one executable per *distinct* phase plan, never a
        # retrace.
        pplan = self.prefill_plan_table
        if pplan == plan:
            self._prefill_step = self._step
        else:
            self._prefill_step = jax.jit(
                lambda params, cache, tokens, pos:
                decode_step(params, cache, tokens, pos, cfg, rc,
                            plan=pplan),
                donate_argnums=(1,) if self.donate else ())

    # --- planner plumbing (the session-level API, now core-owned) ------

    @property
    def kernel_plan(self) -> dict:
        """label -> planner Decision for this core's decode GEMMs.

        Computed lazily on first access through the batched sweep planner
        (plan_workload, backend="vectorized"); the sweep engine's LRU
        cache makes repeat cores over the same shapes free.  The build is
        locked per core: concurrent first accesses must not double-build
        (the second build would be all-hits and overwrite the real
        telemetry)."""
        if self._kernel_plan is None:
            with self._plan_lock:
                if self._kernel_plan is None:
                    self._build_kernel_plan()
        return self._kernel_plan

    def _build_kernel_plan(self) -> None:
        from ..core.llm_workloads import phase_gemms_of_model
        from ..core.planner import plan_workload_by_phase
        from ..core.sweep import measured_cache_delta
        # plan BOTH serving phases: decode GEMMs at M = plan_batch (the
        # paper's M=1 pathology, batched) and prefill GEMMs at
        # M = plan_max_len.  One batched sweep per phase; the sweep
        # engine's LRU makes repeat cores over the same shapes free.
        phases = phase_gemms_of_model(self.cfg, self.plan_max_len,
                                      self.plan_batch)
        by_phase, self._plan_cache_telemetry = measured_cache_delta(
            lambda: plan_workload_by_phase(phases, backend="vectorized"))
        self._kernel_plans = {ph: {d.gemm.label: d for d in ds}
                              for ph, ds in by_phase.items()}
        self._kernel_plan = self._kernel_plans["decode"]

    @property
    def plan_cache_telemetry(self) -> dict:
        """sweep.cache_info() telemetry of this core's kernel_plan build
        (triggers the build on first access): how many of the GEMM
        verdicts were served from the process-wide LRU vs freshly
        evaluated, plus the engine-wide counters (streaming-chunk
        accounting and, on a multi-host mesh, per-process shard
        balance)."""
        _ = self.kernel_plan
        return self._plan_cache_telemetry

    @property
    def kernel_plans(self) -> dict:
        """phase -> {label -> Decision} for both serving phases
        ("prefill" / "decode"); triggers the lazy per-phase plan build."""
        _ = self.kernel_plan
        return self._kernel_plans

    @property
    def phase_verdict_tables(self) -> dict[str, KernelPlanTable]:
        """phase -> raw-verdict KernelPlanTable for both serving phases.
        Never force-ungated; exists for non-quantized cores too (lazy
        plan build)."""
        if self._phase_verdict_tables is None:
            self._phase_verdict_tables = {
                ph: KernelPlanTable.from_decisions(
                    plan.values(), model_name=self.cfg.name)
                for ph, plan in self.kernel_plans.items()}
        return self._phase_verdict_tables

    @property
    def verdict_table(self) -> KernelPlanTable:
        """The decode-phase raw verdicts as a KernelPlanTable (short
        labels).  Unlike `plan_table` it is never force-ungated, and it
        exists for non-quantized cores too (lazy plan build)."""
        if self._verdict_table is None:
            self._verdict_table = self.phase_verdict_tables["decode"]
        return self._verdict_table

    def use_cim_for(self, label: str) -> bool:
        """The planner's "when" gate for one GEMM (feeds
        repro.quant.planned_linear's use_cim_path).  Accepts full
        ("<model> Wq") or short ("Wq") labels; unknown labels raise
        KeyError with the known-label list (the KernelPlanTable
        contract) — model-side label drift must not silently disable
        gating."""
        return self.verdict_table.use_cim(
            strip_model_prefix(label, self.cfg.name))

    # --- the two compiled programs -------------------------------------

    def step(self, cache, tokens, pos):
        """Legacy fixed-batch decode step (uniform scalar position)."""
        return self._step(self.params, cache, tokens, pos)

    def prefill_step(self, cache, tokens, pos):
        """The prefill-phase per-token step: the same decode fn closed
        over the *prefill* plan table (shared program when the phase
        plans coincide)."""
        return self._prefill_step(self.params, cache, tokens, pos)

    def batch_step_for(self, plan):
        """The continuous-batching executable for one (versioned) plan
        table: (params, cache, tokens, pos_vec, active, block_tables) ->
        (logits, cache).  pos_vec (b,) int32, active (b,) bool and
        block_tables (b, max_blocks) int32 are dynamic — join/evict/
        ragged lengths never retrace.

        Variants are memoized per plan table (the table's hash/equality
        is its version) in an LRU bounded by `max_plan_variants`: an
        adaptive engine swapping between plans reuses each variant's
        single compiled program; a plan evicted from the bound recompiles
        if it ever returns (`plan_evictions` counts those drops)."""
        with self._exec_lock:
            fn = self._batch_steps.get(plan)
            if fn is None:
                cfg, rc = self.cfg, self.rc
                # cache donated like `_step` (same platform gate): the
                # paged KV block pools, int8-kv scale pools and per-slot
                # mamba state update in place across steps (no per-token
                # pool copy)
                fn = jax.jit(
                    lambda params, cache, tokens, pos, active,
                    block_tables, _plan=plan:
                    decode_step(params, cache, tokens, pos, cfg, rc,
                                plan=_plan, active=active,
                                block_tables=block_tables),
                    donate_argnums=(1,) if self.donate else ())
                self._batch_steps[plan] = fn
            self._batch_steps.move_to_end(plan)
            while len(self._batch_steps) > self.max_plan_variants:
                self._batch_steps.popitem(last=False)
                self.plan_evictions += 1
        return fn

    @property
    def batch_step(self):
        """The continuous-batching executable for this core's own frozen
        plan table (the non-adaptive path) — see `batch_step_for`."""
        return self.batch_step_for(self.plan_table)

    @property
    def plan_variants(self) -> int:
        """Distinct plan tables with a live jitted batch-step variant."""
        with self._exec_lock:
            return len(self._batch_steps)

    @staticmethod
    def _executables(fn) -> int | None:
        probe = getattr(fn, "_cache_size", None)
        return probe() if probe is not None else None

    @property
    def decode_executables(self) -> int | None:
        """Programs compiled by the fixed-batch step (no-retrace gate:
        exactly 1 after any traffic).  None if the private jax jit-cache
        probe is unavailable."""
        return self._executables(self._step)

    @property
    def prefill_executables(self) -> int | None:
        """Programs compiled by the prefill-phase step (no-retrace gate:
        exactly 1 after any traffic; when the phase plans coincide this
        is the decode step's own count — one shared program).  None if
        the private jax jit-cache probe is unavailable."""
        return self._executables(self._prefill_step)

    @property
    def batch_decode_executables(self) -> int | None:
        """Total programs compiled across every cached batch-step variant
        — the no-retrace gate: equals 1 for frozen-plan traffic and the
        number of distinct plan tables for adaptive traffic (each variant
        compiles exactly once).  None if the private jax jit-cache probe
        is unavailable."""
        with self._exec_lock:
            fns = list(self._batch_steps.values())
        if not fns:
            return 0
        counts = [self._executables(f) for f in fns]
        if any(c is None for c in counts):
            return None
        return sum(counts)

    def route_report(self, batch: int, max_len: int,
                     n_image_tokens: int = 0) -> dict:
        """label -> {route, use_cim, what, where} as actually lowered by
        the jitted decode step (abstract trace, no compute)."""
        from ..models import init_cache
        cache = jax.eval_shape(
            lambda: init_cache(self.cfg, self.rc, batch, max_len,
                               n_image_tokens=n_image_tokens))
        cfg, rc, plan = self.cfg, self.rc, self.plan_table
        with route_trace() as records:
            jax.eval_shape(
                lambda p, c, t, i: decode_step(p, c, t, i, cfg, rc,
                                               plan=plan),
                self.params, cache, _token_struct(cfg, batch),
                jax.ShapeDtypeStruct((), jnp.int32))
        report = {}
        for r in records:
            entry = (self.plan_table.entry(r["label"])
                     if self.plan_table is not None else None)
            report[r["label"]] = {
                "route": r["route"],
                "use_cim": entry.use_cim if entry else False,
                "what": entry.what if entry else "baseline",
                "where": entry.where if entry else "PE"}
        return report
