"""Serving engine: prefill + batched decode with KV caches.

`make_serve_step` builds the jit/pjit-able single-token decode step that
the multi-pod dry-run lowers for decode_32k / long_500k shapes.  The
engine itself adds batched request handling, greedy/temperature sampling,
and prefill-vs-full-forward consistency (tested).

Kernel gating: `ServeSession.kernel_plan` runs the What/When/Where
planner (batched sweep backend — repro.core.sweep, one fused device call,
LRU-cached so every session serving the same model shape reuses the
verdicts) over this session's decode GEMMs; `use_cim_for(label)` is the
per-GEMM gate consulted when routing a projection through the
weight-stationary INT8 path (repro.quant.planned_linear) vs the standard
XLA matmul — the paper's "when NOT to CiM" answer, enforced at runtime.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models import decode_step, forward, init_cache


def make_serve_step(cfg: ModelConfig, rc: RunConfig) -> Callable:
    """(params, cache, tokens, pos) -> (logits, cache) — one decode step.

    This is exactly the fn the dry-run lowers for decode shapes: one new
    token against a seq_len-deep KV cache.
    """
    def step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, rc)
    return step


def make_prefill(cfg: ModelConfig, rc: RunConfig) -> Callable:
    """(params, tokens[, image_embeds]) -> logits — the prefill forward.

    Fills no cache inline (cache writes for prefill re-run the per-token
    decode path in `prefill_into_cache`); used for the prefill_32k shape
    where only the forward matters for lowering."""
    def run(params, tokens, image_embeds=None):
        logits, _ = forward(params, tokens, cfg, rc,
                            image_embeds=image_embeds)
        return logits
    return run


@dataclasses.dataclass
class ServeSession:
    """Minimal batched serving session (greedy or temperature sampling)."""
    cfg: ModelConfig
    rc: RunConfig
    params: Any
    max_len: int
    batch: int
    n_image_tokens: int = 0

    def __post_init__(self):
        self.cache = init_cache(self.cfg, self.rc, self.batch,
                                self.max_len,
                                n_image_tokens=self.n_image_tokens)
        self.pos = 0
        self._step = jax.jit(make_serve_step(self.cfg, self.rc))
        self._kernel_plan = None
        self._plan_cache_telemetry = None
        self._plan_lock = threading.Lock()

    @property
    def kernel_plan(self) -> dict:
        """label -> planner Decision for this session's decode GEMMs.

        Computed lazily on first access through the batched sweep planner
        (plan_workload, backend="vectorized"); the sweep engine's LRU
        cache makes repeat sessions over the same shapes free.  The build
        is locked per session: concurrent first accesses must not
        double-build (the second build would be all-hits and overwrite
        the real telemetry)."""
        if self._kernel_plan is None:
            with self._plan_lock:
                if self._kernel_plan is None:
                    self._build_kernel_plan()
        return self._kernel_plan

    def _build_kernel_plan(self) -> None:
        from ..configs.base import ShapeConfig
        from ..core.llm_workloads import gemms_of_model
        from ..core.planner import plan_workload
        from ..core.sweep import measured_cache_delta
        shape = ShapeConfig("serve", self.max_len, self.batch, "decode")
        gemms = gemms_of_model(self.cfg, shape)
        # hit/miss delta of THIS plan build plus the engine-wide
        # totals: production traffic traces drive cache sizing
        decisions, self._plan_cache_telemetry = measured_cache_delta(
            lambda: plan_workload(gemms, backend="vectorized"))
        self._kernel_plan = {d.gemm.label: d for d in decisions}

    @property
    def plan_cache_telemetry(self) -> dict:
        """sweep.cache_info() telemetry of this session's kernel_plan
        build (triggers the build on first access): how many of the
        session's GEMM verdicts were served from the process-wide LRU vs
        freshly evaluated, plus the engine-wide counters."""
        _ = self.kernel_plan
        return self._plan_cache_telemetry

    def use_cim_for(self, label: str) -> bool:
        """The planner's "when" gate for one GEMM of this session (feeds
        repro.quant.planned_linear's use_cim_path)."""
        d = self.kernel_plan.get(label)
        return bool(d.use_cim) if d is not None else False

    def prefill(self, tokens):
        """Feed a prompt token-by-token through the decode path (keeps a
        single lowered program; fine for small prompts in tests)."""
        logits = None
        for t in range(tokens.shape[1]):
            tok = tokens[:, t:t + 1]
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            jnp.int32(self.pos))
            self.pos += 1
        return logits

    def generate(self, prompt_tokens, n_new: int, temperature: float = 0.0,
                 seed: int = 0):
        logits = self.prefill(prompt_tokens)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        for i in range(n_new):
            out.append(tok)
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            jnp.int32(self.pos))
            self.pos += 1
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key):
        last = logits[:, -1]
        if temperature <= 0.0:
            tok = jnp.argmax(last, axis=-1)
        else:
            tok = jax.random.categorical(key, last / temperature)
        if self.cfg.family == "audio":
            return tok[:, None, :] if tok.ndim == 2 else tok[:, None]
        return tok[:, None].astype(jnp.int32)
