"""Serving engine: prefill + batched decode with KV caches.

`make_serve_step` builds the jit/pjit-able single-token decode step that
the multi-pod dry-run lowers for decode_32k / long_500k shapes.  The
engine itself adds batched request handling, greedy/temperature sampling,
and prefill-vs-full-forward consistency (tested).

Kernel gating: `ServeSession.kernel_plan` runs the What/When/Where
planner (batched sweep backend — repro.core.sweep, one fused device call,
LRU-cached so every session serving the same model shape reuses the
verdicts) over this session's decode GEMMs.  With `quantize=True` the
verdicts become the execution policy: the plan is built *before* jitting,
frozen into a jit-static `KernelPlanTable`, and the jitted decode step
closes over it — gated projection labels lower to the weight-stationary
INT8 Pallas kernel (repro.quant.planned_linear), ungated ones to the
standard XLA matmul, all inside ONE compiled executable (prefill runs the
same per-token step, so prefill and decode share the gate and nothing
retraces after the first step).  `use_cim_for(label)` exposes the
per-GEMM gate; `route_report()` traces the step abstractly and reports
the route each label actually lowered to.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models import decode_step, forward, init, init_cache
from ..models.layers import CIM_ROUTE, route_trace
from ..quant import (KernelPlanTable, quantize_model_params,
                     strip_model_prefix)


def make_serve_step(cfg: ModelConfig, rc: RunConfig,
                    plan: KernelPlanTable | None = None) -> Callable:
    """(params, cache, tokens, pos) -> (logits, cache) — one decode step.

    This is exactly the fn the dry-run lowers for decode shapes: one new
    token against a seq_len-deep KV cache.  `plan` (jit-static) gates
    quantized projections through the INT8 Pallas path per label.
    """
    def step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, rc, plan=plan)
    return step


def make_prefill(cfg: ModelConfig, rc: RunConfig,
                 plan: KernelPlanTable | None = None) -> Callable:
    """(params, tokens[, image_embeds]) -> logits — the prefill forward.

    Fills no cache inline (cache writes for prefill re-run the per-token
    decode path in `prefill_into_cache`); used for the prefill_32k shape
    where only the forward matters for lowering.  Shares `plan` with the
    decode step: one gate for both phases."""
    def run(params, tokens, image_embeds=None):
        logits, _ = forward(params, tokens, cfg, rc,
                            image_embeds=image_embeds, plan=plan)
        return logits
    return run


def cim_fraction(routes: dict) -> float:
    """Fraction of traced projection routes that lowered to the CiM
    INT8 Pallas path (shared by the serve CLI, the dry-run decode cells
    and the gating benchmark — one definition, three surfaces)."""
    vals = [r["route"] if isinstance(r, dict) else r
            for r in routes.values()]
    return sum(v == CIM_ROUTE for v in vals) / max(1, len(vals))


def _token_struct(cfg: ModelConfig, batch: int):
    shape = (batch, 1) + ((cfg.audio.n_codebooks,)
                          if cfg.family == "audio" else ())
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def decode_routes(cfg: ModelConfig, rc: RunConfig, plan: KernelPlanTable,
                  batch: int, max_len: int,
                  n_image_tokens: int = 0) -> dict:
    """label -> executed route of the plan-gated decode step.

    Builds quantized params and cache *abstractly* (jax.eval_shape — no
    allocation, works for full production configs) and traces the step
    under `route_trace`; the result is exactly what the jitted program
    lowers, per projection label.  Used by the dry-run decode cells."""
    step = make_serve_step(cfg, rc, plan)

    def run(key):
        params = quantize_model_params(init(key, cfg))
        cache = init_cache(cfg, rc, batch, max_len,
                          n_image_tokens=n_image_tokens)
        tok = jnp.zeros(_token_struct(cfg, batch).shape, jnp.int32)
        return step(params, cache, tok, jnp.int32(0))

    with route_trace() as records:
        jax.eval_shape(run, jax.random.PRNGKey(0))
    return {r["label"]: r["route"] for r in records}


@dataclasses.dataclass
class ServeSession:
    """Minimal batched serving session (greedy or temperature sampling).

    quantize=True turns the planner verdicts into the execution policy:
    projection weights are INT8-quantized at init, the kernel plan is
    built eagerly (before jitting), and the jitted decode step closes
    over the static KernelPlanTable.  gated=False keeps the quantized
    weights but forces every label onto the standard path — the parity
    baseline for the gated program (identical numerics source, routing
    the only difference)."""
    cfg: ModelConfig
    rc: RunConfig
    params: Any
    max_len: int
    batch: int
    n_image_tokens: int = 0
    quantize: bool = False
    gated: bool = True

    def __post_init__(self):
        self.cache = init_cache(self.cfg, self.rc, self.batch,
                                self.max_len,
                                n_image_tokens=self.n_image_tokens)
        self.pos = 0
        self._kernel_plan = None
        self._plan_cache_telemetry = None
        self._plan_lock = threading.Lock()
        self._verdict_table = None
        self.plan_table = None
        if self.quantize:
            # plan BEFORE jit: the verdicts are static inputs of the one
            # lowered decode program, not runtime state
            table = self.verdict_table
            self.plan_table = table if self.gated else table.ungated()
            self.params = quantize_model_params(self.params)
        self._step = jax.jit(make_serve_step(self.cfg, self.rc,
                                             self.plan_table))

    @property
    def kernel_plan(self) -> dict:
        """label -> planner Decision for this session's decode GEMMs.

        Computed lazily on first access through the batched sweep planner
        (plan_workload, backend="vectorized"); the sweep engine's LRU
        cache makes repeat sessions over the same shapes free.  The build
        is locked per session: concurrent first accesses must not
        double-build (the second build would be all-hits and overwrite
        the real telemetry)."""
        if self._kernel_plan is None:
            with self._plan_lock:
                if self._kernel_plan is None:
                    self._build_kernel_plan()
        return self._kernel_plan

    def _build_kernel_plan(self) -> None:
        from ..configs.base import ShapeConfig
        from ..core.llm_workloads import gemms_of_model
        from ..core.planner import plan_workload
        from ..core.sweep import measured_cache_delta
        shape = ShapeConfig("serve", self.max_len, self.batch, "decode")
        gemms = gemms_of_model(self.cfg, shape)
        # hit/miss delta of THIS plan build plus the engine-wide
        # totals: production traffic traces drive cache sizing
        decisions, self._plan_cache_telemetry = measured_cache_delta(
            lambda: plan_workload(gemms, backend="vectorized"))
        self._kernel_plan = {d.gemm.label: d for d in decisions}

    @property
    def plan_cache_telemetry(self) -> dict:
        """sweep.cache_info() telemetry of this session's kernel_plan
        build (triggers the build on first access): how many of the
        session's GEMM verdicts were served from the process-wide LRU vs
        freshly evaluated, plus the engine-wide counters.  The embedded
        `engine` block also carries the streaming-chunk accounting and —
        for sessions planned on a multi-host mesh — the per-process
        shard balance (rendered by launch.report.shard_balance_table)."""
        _ = self.kernel_plan
        return self._plan_cache_telemetry

    @property
    def verdict_table(self) -> KernelPlanTable:
        """This session's raw verdicts as a KernelPlanTable (short
        labels).  Unlike `plan_table` it is never force-ungated, and it
        exists for non-quantized sessions too (lazy plan build)."""
        if self._verdict_table is None:
            self._verdict_table = KernelPlanTable.from_decisions(
                self.kernel_plan.values(), model_name=self.cfg.name)
        return self._verdict_table

    def use_cim_for(self, label: str) -> bool:
        """The planner's "when" gate for one GEMM of this session (feeds
        repro.quant.planned_linear's use_cim_path).  Accepts full
        ("<model> Wq") or short ("Wq") labels; unknown labels raise
        KeyError with the known-label list (the KernelPlanTable
        contract) — model-side label drift must not silently disable
        gating."""
        return self.verdict_table.use_cim(
            strip_model_prefix(label, self.cfg.name))

    def route_report(self) -> dict:
        """label -> {route, use_cim, what, where} as actually lowered by
        this session's jitted decode step (abstract trace, no compute)."""
        step = make_serve_step(self.cfg, self.rc, self.plan_table)
        with route_trace() as records:
            jax.eval_shape(step, self.params, self.cache,
                           _token_struct(self.cfg, self.batch),
                           jax.ShapeDtypeStruct((), jnp.int32))
        report = {}
        for r in records:
            entry = (self.plan_table.entry(r["label"])
                     if self.plan_table is not None else None)
            report[r["label"]] = {
                "route": r["route"],
                "use_cim": entry.use_cim if entry else False,
                "what": entry.what if entry else "baseline",
                "where": entry.where if entry else "PE"}
        return report

    @property
    def decode_executables(self) -> int | None:
        """How many programs the jitted decode step compiled (the
        no-retrace gate expects exactly 1 after any amount of traffic).
        None when the private jax jit-cache probe is unavailable."""
        probe = getattr(self._step, "_cache_size", None)
        return probe() if probe is not None else None

    def reset(self) -> None:
        """Clear the KV cache and position for a fresh request; the
        compiled decode step (and its plan gate) is reused as-is."""
        self.cache = init_cache(self.cfg, self.rc, self.batch,
                                self.max_len,
                                n_image_tokens=self.n_image_tokens)
        self.pos = 0

    def prefill(self, tokens):
        """Feed a prompt token-by-token through the decode path (keeps a
        single lowered program; fine for small prompts in tests)."""
        logits = None
        for t in range(tokens.shape[1]):
            tok = tokens[:, t:t + 1]
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            jnp.int32(self.pos))
            self.pos += 1
        return logits

    def generate(self, prompt_tokens, n_new: int, temperature: float = 0.0,
                 seed: int = 0):
        logits = self.prefill(prompt_tokens)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        for i in range(n_new):
            out.append(tok)
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            jnp.int32(self.pos))
            self.pos += 1
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key):
        last = logits[:, -1]
        if temperature <= 0.0:
            tok = jnp.argmax(last, axis=-1)
        else:
            tok = jax.random.categorical(key, last / temperature)
        if self.cfg.family == "audio":
            return tok[:, None, :] if tok.ndim == 2 else tok[:, None]
        return tok[:, None].astype(jnp.int32)
