"""Serving engine: prefill + batched decode with KV caches.

`make_serve_step` builds the jit/pjit-able single-token decode step that
the multi-pod dry-run lowers for decode_32k / long_500k shapes.  The
serving stack splits into two layers on top of it:

  * `DecodeCore` (repro.serving.core) — the immutable compiled core:
    params, jit-static KernelPlanTable, and the jitted decode
    executables, frozen before any traffic;
  * the mutable request layers — the legacy fixed-batch `ServeSession`
    below (one cache, one uniform position, greedy/temperature
    sampling), and the slot-scheduled `ContinuousBatchingEngine`
    (repro.serving.scheduler) for ragged request streams.

Kernel gating: `ServeSession.kernel_plan` runs the What/When/Where
planner (batched sweep backend — repro.core.sweep, one fused device call,
LRU-cached so every session serving the same model shape reuses the
verdicts) over this session's decode GEMMs.  With `quantize=True` the
verdicts become the execution policy: the plan is built *before* jitting,
frozen into a jit-static `KernelPlanTable`, and the jitted decode step
closes over it — gated projection labels lower to the weight-stationary
INT8 Pallas kernel (repro.quant.planned_linear), ungated ones to the
standard XLA matmul, all inside ONE compiled executable (prefill runs the
same per-token step, so prefill and decode share the gate and nothing
retraces after the first step).  `use_cim_for(label)` exposes the
per-GEMM gate; `route_report()` traces the step abstractly and reports
the route each label actually lowered to.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..models import decode_step, forward, init_cache
from ..models.layers import CIM_ROUTE
from ..quant import KernelPlanTable
from .core import DecodeCore, _token_struct, sample_token


def make_serve_step(cfg: ModelConfig, rc: RunConfig,
                    plan: KernelPlanTable | None = None) -> Callable:
    """(params, cache, tokens, pos) -> (logits, cache) — one decode step.

    This is exactly the fn the dry-run lowers for decode shapes: one new
    token against a seq_len-deep KV cache.  `plan` (jit-static) gates
    quantized projections through the INT8 Pallas path per label.
    """
    def step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, rc, plan=plan)
    return step


def make_prefill(cfg: ModelConfig, rc: RunConfig,
                 plan: KernelPlanTable | None = None) -> Callable:
    """(params, tokens[, image_embeds]) -> logits — the prefill forward.

    Fills no cache inline (cache writes for prefill re-run the per-token
    decode path in `prefill_into_cache`); used for the prefill_32k shape
    where only the forward matters for lowering.  Pass the *prefill*
    phase's plan table (DecodeCore.prefill_plan_table): each serving
    phase is gated by its own What/When/Where verdicts."""
    def run(params, tokens, image_embeds=None):
        logits, _ = forward(params, tokens, cfg, rc,
                            image_embeds=image_embeds, plan=plan)
        return logits
    return run


def cim_fraction(routes: dict) -> float:
    """Fraction of traced projection routes that lowered to the CiM
    INT8 Pallas path (shared by the serve CLI, the dry-run decode cells
    and the gating benchmark — one definition, three surfaces)."""
    vals = [r["route"] if isinstance(r, dict) else r
            for r in routes.values()]
    return sum(v == CIM_ROUTE for v in vals) / max(1, len(vals))


def decode_routes(cfg: ModelConfig, rc: RunConfig, plan: KernelPlanTable,
                  batch: int, max_len: int,
                  n_image_tokens: int = 0) -> dict:
    """label -> executed route of the plan-gated decode step.

    Builds quantized params and cache *abstractly* (jax.eval_shape — no
    allocation, works for full production configs) and traces the step
    under `route_trace`; the result is exactly what the jitted program
    lowers, per projection label.  Used by the dry-run decode cells."""
    from ..models import init
    from ..models.layers import route_trace
    from ..quant import quantize_model_params
    step = make_serve_step(cfg, rc, plan)

    def run(key):
        params = quantize_model_params(init(key, cfg))
        cache = init_cache(cfg, rc, batch, max_len,
                          n_image_tokens=n_image_tokens)
        tok = jnp.zeros(_token_struct(cfg, batch).shape, jnp.int32)
        return step(params, cache, tok, jnp.int32(0))

    with route_trace() as records:
        jax.eval_shape(run, jax.random.PRNGKey(0))
    return {r["label"]: r["route"] for r in records}


@dataclasses.dataclass
class ServeSession:
    """Minimal fixed-batch serving session (greedy or temperature
    sampling): all `batch` lanes advance in lockstep at one uniform
    position over one contiguous KV cache.  For ragged request streams
    (per-request join/evict, paged KV) use
    repro.serving.ContinuousBatchingEngine over the same DecodeCore.

    quantize=True turns the planner verdicts into the execution policy:
    projection weights are INT8-quantized at init, the kernel plan is
    built eagerly (before jitting), and the jitted decode step closes
    over the static KernelPlanTable.  gated=False keeps the quantized
    weights but forces every label onto the standard path — the parity
    baseline for the gated program (identical numerics source, routing
    the only difference)."""
    cfg: ModelConfig
    rc: RunConfig
    params: Any
    max_len: int
    batch: int
    n_image_tokens: int = 0
    quantize: bool = False
    gated: bool = True
    # weight precision of the quantized path: "int8" / "int4" / "fp8"
    precision: str = "int8"

    def __post_init__(self):
        self.core = DecodeCore(self.cfg, self.rc, self.params,
                               quantize=self.quantize, gated=self.gated,
                               precision=self.precision,
                               plan_batch=self.batch,
                               plan_max_len=self.max_len)
        self.params = self.core.params       # quantized if quantize=True
        self.plan_table = self.core.plan_table
        self.prefill_plan_table = self.core.prefill_plan_table
        self._step = self.core._step
        self._prefill_step = self.core._prefill_step
        self.cache = init_cache(self.cfg, self.rc, self.batch,
                                self.max_len,
                                n_image_tokens=self.n_image_tokens)
        self.pos = 0

    # --- planner plumbing: delegated to the compiled core --------------

    @property
    def kernel_plan(self) -> dict:
        """label -> planner Decision for this session's decode GEMMs
        (lazy; LRU-cached across sessions — see DecodeCore.kernel_plan)."""
        return self.core.kernel_plan

    @property
    def _kernel_plan(self):
        return self.core._kernel_plan

    @property
    def plan_cache_telemetry(self) -> dict:
        """sweep.cache_info() telemetry of this session's kernel_plan
        build (triggers the build on first access) — see
        DecodeCore.plan_cache_telemetry."""
        return self.core.plan_cache_telemetry

    @property
    def verdict_table(self) -> KernelPlanTable:
        """This session's raw verdicts as a KernelPlanTable (short
        labels).  Unlike `plan_table` it is never force-ungated, and it
        exists for non-quantized sessions too (lazy plan build)."""
        return self.core.verdict_table

    def use_cim_for(self, label: str) -> bool:
        """The planner's "when" gate for one GEMM of this session —
        see DecodeCore.use_cim_for."""
        return self.core.use_cim_for(label)

    def route_report(self) -> dict:
        """label -> {route, use_cim, what, where} as actually lowered by
        this session's jitted decode step (abstract trace, no compute)."""
        return self.core.route_report(self.batch, self.max_len,
                                      self.n_image_tokens)

    @property
    def decode_executables(self) -> int | None:
        """How many programs the jitted decode step compiled (the
        no-retrace gate expects exactly 1 after any amount of traffic).
        None when the private jax jit-cache probe is unavailable."""
        return self.core.decode_executables

    @property
    def prefill_executables(self) -> int | None:
        """Programs compiled by the prefill-phase step — see
        DecodeCore.prefill_executables."""
        return self.core.prefill_executables

    @property
    def phase_verdict_tables(self) -> dict:
        """phase -> raw-verdict KernelPlanTable — see
        DecodeCore.phase_verdict_tables."""
        return self.core.phase_verdict_tables

    # --- request state --------------------------------------------------

    def reset(self) -> None:
        """Clear the KV cache and position for a fresh request; the
        compiled decode step (and its plan gate) is reused as-is."""
        self.cache = init_cache(self.cfg, self.rc, self.batch,
                                self.max_len,
                                n_image_tokens=self.n_image_tokens)
        self.pos = 0

    def prefill(self, tokens):
        """Feed a prompt token-by-token through the *prefill-phase* step
        — the same per-token program shape as decode, gated by the
        prefill plan table (one lowered program per phase; they share a
        program when the phase plans coincide)."""
        logits = None
        for t in range(tokens.shape[1]):
            tok = tokens[:, t:t + 1]
            logits, self.cache = self._prefill_step(
                self.params, self.cache, tok, jnp.int32(self.pos))
            self.pos += 1
        return logits

    def generate(self, prompt_tokens, n_new: int, temperature: float = 0.0,
                 seed: int = 0):
        logits = self.prefill(prompt_tokens)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, temperature, key)
        for i in range(n_new):
            out.append(tok)
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            jnp.int32(self.pos))
            self.pos += 1
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits, temperature, key):
        return sample_token(self.cfg, logits, temperature, key)
