"""Slot-scheduled continuous batching over one compiled decode core.

Production traffic is a stream of ragged requests, not one fixed-shape
batch.  This module turns the plan-gated decode step into a request
server:

  * an **admission queue** (FIFO) of `Request`s;
  * **slots**: the jitted step always runs at a fixed batch of
    `n_slots` lanes; a request joins a free slot, decodes in place, and
    is evicted on EOS / max-tokens — mid-decode, without retracing —
    via the step's jit-dynamic active-slot mask;
  * **paged KV**: attention caches live in a shared block pool
    (models.model.init_paged_cache); a host-side `BlockAllocator` hands
    fixed-size blocks to slots and reclaims them on eviction, so ragged
    lengths share one executable and one pool;
  * **piggy-backed prefill**: a joining request's prompt tokens stream
    through the *same* decode step, one per engine iteration, while the
    other slots keep generating — prefill and decode share the plan
    gate, the executable, and the batch;
  * a **sync-free token loop**: greedy traffic runs one step ahead of
    the host — step t's sampled tokens stay on device and feed step t+1
    directly (a jitted where-select mixes device tokens with host
    prompt tokens per lane), and the host blocks on step t's tokens
    only after step t+1 is dispatched.  When the core donates its cache
    argument (`DecodeCore.donate` — accelerator default), the paged-KV
    pools update in place (no per-token copy;
    `telemetry()["aggregate"]["kv_donation_ok"]` probes it on the first
    step, and stays None when donation is off).  Temperature requests
    need host logits between steps, so they flip the engine to
    synchronous retire;
  * **per-request telemetry**: TTFT, queue wait, decode tokens/s, plus
    engine-level queue depth / slot occupancy / block usage samples and
    a `decode_step_breakdown` (dispatch vs host-fetch vs telemetry time
    per step);
  * **adaptive planning** (optional): an engine given a
    `repro.core.plan_service.PlanService` consults it every step at the
    live operating point (active-slot count, deepest position); when the
    shape bucket's verdict flips, the engine **hot-swaps** the decode
    plan — the new plan's executable is fetched (compiling at most once,
    off the critical decode step, via a discarded warm-up call) from
    `DecodeCore.batch_step_for`'s bounded variant cache, then the step
    pointer flips.  Bucket transitions, plan swaps and swap latencies
    land in `telemetry()["adaptive"]`.

The scheduler is pure host-side Python around `DecodeCore.batch_step`;
everything it varies per step (tokens, positions, active mask, block
tables) is a jit-*dynamic* input, so any traffic pattern hits exactly
one compiled executable per distinct plan (`decode_executables == 1`
frozen, `== n_distinct_plans` adaptive).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..models import period_slots
from ..models.model import init_paged_cache
from .core import DecodeCore, sample_token


@dataclasses.dataclass
class Request:
    """One serving request: a prompt plus generation settings.

    Telemetry fields (t_*, tokens, ...) are engine-written; times are
    seconds on the engine clock.  `tokens` holds generated token ids
    (ints; audio: (n_codebooks,) int arrays)."""
    rid: Any
    prompt: Any                       # (P,) int32 (audio: (P, nb))
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: int | None = None
    # --- engine-written telemetry ---
    state: str = "new"                # new | queued | running | done
    done_reason: str | None = None    # eos | max_tokens
    t_submit: float | None = None
    t_admit: float | None = None
    t_first: float | None = None      # first generated token (TTFT ref)
    t_done: float | None = None
    tokens: list = dataclasses.field(default_factory=list)
    first_logits: Any = None          # recorded iff record_logits=True

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class BlockAllocator:
    """Host-side free list over the paged KV pool's physical blocks.

    Allocation is all-or-nothing per request (the engine reserves the
    request's full horizon at admission, so a running request can never
    hit pool exhaustion mid-decode — admission control is the only
    back-pressure point)."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, -1, -1))
        self._free_set = set(self._free)
        self.peak_in_use = 0

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            return None
        blocks = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(blocks)
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return blocks

    def free(self, blocks: list[int]) -> None:
        """Return blocks to the pool.  A double-free or an id the pool
        never issued would silently corrupt the free list (free_blocks
        could exceed n_blocks and a block could be handed to two slots),
        so both raise — and validation happens before any mutation, so a
        bad call leaves the allocator state untouched."""
        bad = [b for b in blocks
               if not (0 <= b < self.n_blocks) or b in self._free_set]
        if len(set(blocks)) != len(blocks):
            bad.extend(b for b in set(blocks)
                       if blocks.count(b) > 1 and b not in bad)
        if bad:
            raise ValueError(
                f"invalid free of block ids {sorted(set(bad))}: "
                f"double-free or id outside pool [0, {self.n_blocks})")
        self._free.extend(reversed(blocks))
        self._free_set.update(blocks)


class _Slot:
    """Mutable per-slot decode state (host-side only)."""

    def __init__(self, req: Request, blocks: list[int]):
        self.req = req
        self.blocks = blocks
        self.pos = 0          # tokens written into this slot's KV/state
        self.n_fed = 0        # prompt tokens consumed so far
        self.n_gen = 0        # tokens generated so far (counted at
                              # dispatch; retire attributes them)
        self.last_tok = None  # last retired token (host copy)
        self.dev_feed = False  # next feed comes from the previous
                               # step's on-device greedy tokens
        self.draining = False  # hit max_new_tokens at dispatch: excluded
                               # from further steps, evicted at retire

    @property
    def prefilling(self) -> bool:
        return self.n_fed < self.req.prompt_len

    def next_token(self):
        return (self.req.prompt[self.n_fed] if self.prefilling
                else self.last_tok)


class _InFlight:
    """One dispatched-but-not-retired decode step (the one-step-deep
    async queue of the sync-free token loop): the device-resident logits
    and greedy tokens plus the attribution records deciding which lanes'
    tokens belong to which requests once the host looks."""

    __slots__ = ("logits", "greedy", "recs")

    def __init__(self, logits, greedy, recs):
        self.logits = logits
        self.greedy = greedy
        self.recs = recs      # [(lane, slot, is_first, is_final), ...]


class ContinuousBatchingEngine:
    """Request server: admission queue + slot-scheduled continuous
    batching + paged KV, over one immutable `DecodeCore`.

    Every engine iteration (`step()`) advances all active slots by one
    token through the single jitted masked decode step: joining requests
    stream prompt tokens (piggy-backed prefill), running requests feed
    their last sampled token, and finished requests leave their slot the
    moment EOS / max-tokens hits — the next queued request takes it on
    the following step.
    """

    def __init__(self, core: DecodeCore, n_slots: int, max_len: int,
                 block_size: int = 8, n_kv_blocks: int | None = None,
                 seed: int = 0, record_logits: bool = False,
                 plan_service=None, pipeline: bool = True,
                 telemetry_every: int = 1,
                 clock: Callable[[], float] = time.perf_counter):
        if core.cfg.family == "vlm":
            raise NotImplementedError(
                "continuous batching does not yet thread per-request "
                "image embeddings through cross-attention slots")
        if plan_service is not None and core.plan_table is None:
            raise ValueError(
                "adaptive planning needs a plan-gated core: build the "
                "DecodeCore with quantize=True so plan tables route the "
                "decode step (an unquantized core ignores verdicts)")
        self.core = core
        self.cfg = core.cfg
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.record_logits = record_logits
        self.clock = clock
        self.needs_kv = any(s.mixer == "attn"
                            for s in period_slots(core.cfg))
        self.max_blocks = max(1, math.ceil(max_len / block_size))
        if n_kv_blocks is None:
            n_kv_blocks = self.max_blocks * n_slots   # full provisioning
        self.allocator = BlockAllocator(n_kv_blocks if self.needs_kv
                                        else 0)
        self.cache = init_paged_cache(core.cfg, core.rc, n_slots,
                                      max(1, n_kv_blocks), block_size)
        self.block_tables = np.zeros((n_slots, self.max_blocks), np.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[_Slot | None] = [None] * n_slots
        self._key = jax.random.PRNGKey(seed)
        self._t0: float | None = None
        # sync-free token loop: step t's host fetch overlaps step t+1's
        # dispatch.  Temperature sampling needs host logits before the
        # next feed, so any temperature>0 submit flips the engine to
        # synchronous retire (pipeline=False forces it outright).
        self.pipeline = pipeline
        self.telemetry_every = max(1, telemetry_every)
        self._sync = False
        self._inflight: _InFlight | None = None
        self._device_toks = None      # prev step's greedy (device)
        self._select_fn = None        # jitted host/device token mix
        self._greedy_fn = None        # jitted greedy sampler
        self.donation_ok: bool | None = None  # cache-donation probe
        # counters + per-step samples (the telemetry block)
        self.completed: list[Request] = []
        self.evictions = 0
        self.steps = 0
        self.queue_depth_samples: list[int] = []
        self.occupancy_samples: list[float] = []
        # decode_step_breakdown accumulators (seconds)
        self.dispatch_s = 0.0
        self.host_fetch_s = 0.0
        self.telemetry_s = 0.0
        # adaptive planning: current plan + hot-swap telemetry
        self.plan_service = plan_service
        self._plan = core.plan_table
        self._step_fn = None          # resolved lazily / on swap
        self._bucket: tuple[int, int] | None = None
        self.bucket_transitions = 0
        self.plan_swaps = 0
        self.swap_latencies_s: list[float] = []
        # phase-split gating (frozen-plan engines only — an attached
        # plan service owns the plan): a step whose live slots are ALL
        # still prefilling runs under the prefill-phase table, any
        # decoding slot makes it a decode-phase step.  Both variants
        # come from the core's bounded executable cache, so steady
        # mixed traffic serves from at most two compiled programs.
        self._phase_tables = {"decode": core.plan_table,
                              "prefill": core.prefill_plan_table}
        self._phase = "decode"
        self.phase_switches = 0
        self.phase_steps = {"prefill": 0, "decode": 0}

    # --- admission ------------------------------------------------------

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = self.clock()
        return self.clock() - self._t0

    def _blocks_needed(self, req: Request) -> int:
        if not self.needs_kv:
            return 0
        return math.ceil((req.prompt_len + req.max_new_tokens)
                         / self.block_size)

    def submit(self, req: Request) -> None:
        """Queue a request (validates it can ever be admitted)."""
        horizon = req.prompt_len + req.max_new_tokens
        if horizon > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt_len + max_new_tokens = "
                f"{horizon} exceeds engine max_len {self.max_len}")
        if self._blocks_needed(req) > self.allocator.n_blocks:
            raise ValueError(
                f"request {req.rid} needs {self._blocks_needed(req)} KV "
                f"blocks; the pool only has {self.allocator.n_blocks}")
        req.prompt = np.asarray(req.prompt, np.int32)
        if req.temperature > 0.0:
            # the pipelined loop feeds on-device greedy tokens; a
            # categorical draw needs host logits before the next feed,
            # so temperature traffic degrades to synchronous retire
            self._sync = True
        req.state = "queued"
        req.t_submit = self._now()
        self.queue.append(req)

    def _reset_slot_state(self, i: int) -> None:
        """Zero the joining slot's O(1) caches (mamba state / conv
        carry).  Attention needs nothing: stale pool blocks are dead by
        construction (per-slot lens mask + freed block ids)."""
        for c, entry in enumerate(self.cache):
            if "state" in entry:
                self.cache[c] = {
                    "state": entry["state"].at[:, i].set(0.0),
                    "conv": entry["conv"].at[:, i].set(0.0)}

    def _admit(self) -> None:
        """FIFO admission: the queue head takes the first free slot if
        its full KV horizon fits in the pool (no skipping — head-of-line
        order keeps TTFT fairness)."""
        for i in range(self.n_slots):
            if not self.queue:
                return
            if self.slots[i] is not None:
                continue
            req = self.queue[0]
            blocks = self.allocator.alloc(self._blocks_needed(req))
            if blocks is None:
                return                      # pool pressure: wait
            self.queue.popleft()
            self.block_tables[i, :] = 0
            if blocks:
                self.block_tables[i, :len(blocks)] = blocks
            self._reset_slot_state(i)
            self.slots[i] = _Slot(req, blocks)
            req.state = "running"
            req.t_admit = self._now()

    # --- the engine iteration -------------------------------------------

    @property
    def active_slots(self) -> int:
        return sum(s is not None for s in self.slots)

    def _token_batch(self) -> np.ndarray:
        shape = ((self.n_slots, 1, self.cfg.audio.n_codebooks)
                 if self.cfg.family == "audio" else (self.n_slots, 1))
        toks = np.zeros(shape, np.int32)
        for i, st in enumerate(self.slots):
            if st is not None:
                tok = st.next_token()
                # a pipelined slot's last token may still be on device
                # (retired next step); its lane is overridden by the
                # device-token select in _dispatch, so 0 is a dead value
                toks[i, 0] = 0 if tok is None else tok
        return toks

    def _consult_plan_service(self) -> None:
        """Ask the plan service for the current operating point's bucket
        verdicts; hot-swap the decode plan if they differ from the one
        being served (the swap compiles at most once, off the decode hot
        path — see `_swap_plan`)."""
        n_active = self.active_slots
        max_pos = max(s.pos for s in self.slots if s is not None)
        bucket, table = self.plan_service.lookup(n_active, max_pos)
        if bucket != self._bucket:
            if self._bucket is not None:
                self.bucket_transitions += 1
            self._bucket = bucket
        if table != self._plan:
            self._swap_plan(table)

    def _swap_plan(self, table) -> None:
        """Compile-then-swap: fetch the new plan's executable from the
        core's bounded variant cache and warm it with a discarded
        all-inactive call (so any compile happens *here*, between steps,
        never inside the decode hot path), then flip the step pointer.
        The full fetch+warm latency is recorded as the swap latency —
        near-zero when the variant is already compiled."""
        t0 = self.clock()
        fn = self.core.batch_step_for(table)
        # the warm call donates self.cache like every step; all lanes
        # are inactive so the returned cache is contents-identical —
        # rebind it (the donated input buffers are gone)
        warm_toks = self._mix_tokens(self._token_batch(),
                                     np.zeros(self.n_slots, bool))
        warm_logits, warmed = fn(self.core.params, self.cache, warm_toks,
                                 np.zeros(self.n_slots, np.int32),
                                 np.zeros(self.n_slots, bool),
                                 self.block_tables)
        jax.block_until_ready(warm_logits)
        self.cache = warmed
        self.swap_latencies_s.append(self.clock() - t0)
        self._plan = table
        self._step_fn = fn
        self.plan_swaps += 1

    def _select_phase_table(self) -> None:
        """Per-step phase gating for frozen-plan engines: serve a
        pure-prefill step (every live slot still feeding its prompt)
        under the prefill-phase plan table, anything else under the
        decode table.  A phase flip swaps the step pointer through the
        core's bounded variant cache — each phase's program compiles at
        most once, steady traffic never retraces."""
        live = [s for s in self.slots if s is not None and not s.draining]
        phase = ("prefill" if live and all(s.prefilling for s in live)
                 else "decode")
        if phase != self._phase:
            self._phase = phase
            self.phase_switches += 1
            self._plan = self._phase_tables[phase]
            self._step_fn = self.core.batch_step_for(self._plan)
        self.phase_steps[phase] += 1

    @property
    def _pipelined(self) -> bool:
        return self.pipeline and not self._sync

    def _mix_tokens(self, host_toks: np.ndarray, use_dev: np.ndarray):
        """Per-lane token feed: the previous step's on-device greedy
        token where `use_dev`, the host token (prompt / synchronous
        last_tok) elsewhere.  Tokens ALWAYS flow through the jitted
        select — even all-host batches — because the decode step's jit
        cache keys on input sharding/commitment, and mixing raw numpy
        steps with select-output steps would compile the program
        twice."""
        if self._select_fn is None:
            self._select_fn = jax.jit(jnp.where)
        mask = use_dev.reshape((self.n_slots, 1)
                               + (1,) * (host_toks.ndim - 2))
        dev = (self._device_toks if self._device_toks is not None
               else host_toks)
        return self._select_fn(mask, dev, host_toks)

    def step(self) -> bool:
        """One engine iteration.  Returns False when idle (nothing
        active, nothing admissible, nothing in flight).

        Pipelined (the default, greedy traffic): dispatch step *t* to
        the device first, *then* block on step *t-1*'s tokens — the host
        fetch of one step overlaps the device compute of the next.
        Synchronous (temperature traffic / pipeline=False): dispatch and
        retire the same step, the pre-pipeline behavior."""
        if not self._pipelined and self._inflight is not None:
            self._retire(self._inflight)    # mode flipped: flush first
        t0 = self.clock()
        self._admit()
        if self.steps % self.telemetry_every == 0:
            self.queue_depth_samples.append(len(self.queue))
            self.occupancy_samples.append(self.active_slots / self.n_slots)
        self.telemetry_s += self.clock() - t0
        if not any(s is not None and not s.draining for s in self.slots):
            if self._inflight is not None:
                self._retire(self._inflight)
                return True
            return False
        if self.plan_service is not None:
            self._consult_plan_service()
        elif self._phase_tables["prefill"] is not None:
            self._select_phase_table()
        if self._step_fn is None:
            self._step_fn = self.core.batch_step_for(self._plan)
        prev = self._inflight
        self._inflight = self._dispatch()
        if prev is not None:
            self._retire(prev, keep_inflight=True)
        if not self._pipelined:
            self._retire(self._inflight)
        return True

    def _dispatch(self) -> _InFlight:
        """Enqueue one decode step on the device and account for it.

        Token feed is device-resident: a lane whose previous token is
        still in flight takes it from the prior step's on-device greedy
        array (no host round-trip); prompt lanes and synchronous-mode
        lanes take host tokens.  All per-slot bookkeeping (pos / fed /
        generated counts, max-token draining) happens here, at dispatch;
        `_retire` only attributes the finished tokens to requests."""
        t0 = self.clock()
        host_toks = self._token_batch()
        pos = np.array([0 if s is None else s.pos for s in self.slots],
                       np.int32)
        active = np.array([s is not None and not s.draining
                           for s in self.slots], bool)
        use_dev = np.array([s is not None and s.dev_feed
                            and not s.prefilling for s in self.slots],
                           bool)
        tokens = self._mix_tokens(host_toks, use_dev)
        probe = None
        if self.donation_ok is None and self.core.donate:
            probe = next((leaf for leaf in jax.tree.leaves(self.cache)
                          if hasattr(leaf, "is_deleted")), None)
        logits, self.cache = self._step_fn(
            self.core.params, self.cache, tokens, pos, active,
            self.block_tables)
        if probe is not None:
            # the jitted step donates its cache argument; if XLA
            # accepted the donation the input buffer is dead the moment
            # the call is dispatched (pools update in place, no copy)
            self.donation_ok = bool(probe.is_deleted())
        if self._greedy_fn is None:
            cfg = self.cfg
            self._greedy_fn = jax.jit(
                lambda lg: sample_token(cfg, lg, 0.0, None))
        greedy = self._greedy_fn(logits)
        self._device_toks = greedy
        self.steps += 1
        recs = []
        for i, st in enumerate(self.slots):
            if st is None or st.draining:
                continue
            fed_prompt = st.prefilling
            st.pos += 1
            if fed_prompt:
                st.n_fed += 1
                if st.prefilling:
                    st.dev_feed = False
                    continue        # mid-prompt: sampled token discarded
            st.n_gen += 1
            st.dev_feed = True
            final = st.n_gen >= st.req.max_new_tokens
            if final:
                # final token: stop dispatching this lane now (the KV
                # horizon is exactly spent); the slot is evicted when
                # this step retires
                st.draining = True
            recs.append((i, st, st.n_gen == 1, final))
        self.dispatch_s += self.clock() - t0
        return _InFlight(logits, greedy, recs)

    def _retire(self, inf: _InFlight, keep_inflight: bool = False) -> None:
        """Block on one dispatched step's tokens and attribute them:
        append to requests, stamp TTFT, record first-logits (one batched
        transfer for exactly the lanes that produced their first token),
        and evict EOS / max-token slots."""
        if not keep_inflight:
            self._inflight = None
        elif self._inflight is inf:
            self._inflight = None
        t0 = self.clock()
        greedy = np.asarray(inf.greedy)     # blocks until the step ran
        first_rows = {}
        if self.record_logits:
            idxs = [i for i, st, first, _ in inf.recs
                    if first and st.req.state != "done"]
            if idxs:
                rows = np.asarray(
                    jax.device_get(inf.logits[np.array(idxs), -1]),
                    np.float32)
                first_rows = dict(zip(idxs, rows))
        self.host_fetch_s += self.clock() - t0
        now = self._now()
        for i, st, first, final in inf.recs:
            req = st.req
            if req.state == "done":
                continue    # evicted at an earlier retire (EOS lag):
                            # this lane's speculative token is discarded
            tok = self._sample_slot(i, st, inf.logits, greedy)
            st.last_tok = tok
            req.tokens.append(tok)
            if first:
                req.t_first = now
                if i in first_rows:
                    req.first_logits = first_rows[i]
            hit_eos = (req.eos_id is not None
                       and self.cfg.family != "audio"
                       and int(tok) == req.eos_id)
            if hit_eos or final:
                self._evict(i, "eos" if hit_eos else "max_tokens", now)
        if not self._pipelined:
            self._device_toks = None    # sync mode: host tokens only

    def _sample_slot(self, i: int, st: _Slot, logits, greedy):
        """Next token for slot i: batchwide greedy argmax unless the
        request asked for temperature sampling (then a per-slot
        categorical draw from the engine's PRNG stream — synchronous
        mode only, see `submit`)."""
        if st.req.temperature <= 0.0:
            return greedy[i, 0]
        self._key, sub = jax.random.split(self._key)
        row = np.asarray(jax.device_get(logits[i, -1]),
                         np.float32) / st.req.temperature
        tok = jax.random.categorical(sub, row, axis=-1)
        return np.asarray(jax.device_get(tok), np.int32)

    def _evict(self, i: int, reason: str, now: float) -> None:
        st = self.slots[i]
        self.allocator.free(st.blocks)
        self.slots[i] = None
        self.evictions += 1
        st.req.state = "done"
        st.req.done_reason = reason
        st.req.t_done = now
        self.completed.append(st.req)

    # --- driving loops ----------------------------------------------------

    def run(self, requests: list[Request],
            arrival_times: list[float] | None = None,
            timeout_s: float = 300.0) -> dict:
        """Drive an open-loop arrival process to completion.

        `arrival_times[i]` is request i's arrival offset (seconds from
        run start) on the engine clock; None submits everything up
        front.  Returns `telemetry()`."""
        self._t0 = None
        t_start = self._now()           # pins the epoch
        target = len(self.completed) + len(requests)
        pending = sorted(zip(arrival_times or [0.0] * len(requests),
                             requests), key=lambda p: p[0])
        while len(self.completed) < target:
            now = self._now()
            if now - t_start > timeout_s:
                raise RuntimeError(
                    f"engine run exceeded {timeout_s}s with "
                    f"{len(pending)} arrivals pending")
            while pending and pending[0][0] <= now:
                self.submit(pending.pop(0)[1])
            if not self.step() and pending:
                # idle until the next arrival is due (open-loop clock)
                time.sleep(min(0.001, max(0.0, pending[0][0]
                                          - self._now())))
        return self.telemetry()

    def drain(self, timeout_s: float = 300.0) -> None:
        """Step until queue + slots are empty."""
        t0 = self._now()
        while self.step():
            if self._now() - t0 > timeout_s:
                raise RuntimeError(f"drain exceeded {timeout_s}s")

    # --- telemetry --------------------------------------------------------

    @property
    def decode_executables(self) -> int | None:
        """Compiled program count of the masked batch step — the
        continuous-batching no-retrace gate (expects exactly 1)."""
        return self.core.batch_decode_executables

    def telemetry(self) -> dict:
        """Per-request + engine-aggregate serving telemetry."""
        reqs = []
        for r in self.completed:
            # a request can complete without ever generating a token
            # (t_first is None — e.g. evicted before its first decode);
            # its latency fields are None and it is excluded from the
            # TTFT percentiles below rather than crashing them
            decode_s = ((r.t_done - r.t_first)
                        if r.t_first is not None and len(r.tokens) > 1
                        else None)
            reqs.append({
                "rid": r.rid,
                "prompt_len": r.prompt_len,
                "new_tokens": len(r.tokens),
                "done_reason": r.done_reason,
                "queue_wait_s": (r.t_admit - r.t_submit
                                 if r.t_admit is not None else None),
                "ttft_s": (r.t_first - r.t_submit
                           if r.t_first is not None else None),
                "decode_tokens_per_s": (
                    (len(r.tokens) - 1) / decode_s
                    if decode_s and decode_s > 0 else None),
            })
        ttfts = [r["ttft_s"] for r in reqs if r["ttft_s"] is not None]
        total_tokens = sum(r["new_tokens"] for r in reqs)
        t_done = [r.t_done for r in self.completed]
        makespan = max(t_done) if t_done else 0.0
        dts = [r["decode_tokens_per_s"] for r in reqs
               if r["decode_tokens_per_s"]]
        agg = {
            "completed": len(self.completed),
            "evictions": self.evictions,
            "eos_evictions": sum(r["done_reason"] == "eos" for r in reqs),
            "steps": self.steps,
            "total_new_tokens": total_tokens,
            "engine_tokens_per_s": (total_tokens / makespan
                                    if makespan > 0 else None),
            "request_tokens_per_s_mean": (float(np.mean(dts))
                                          if dts else None),
            "ttft_mean_s": float(np.mean(ttfts)) if ttfts else None,
            "ttft_p50_s": float(np.percentile(ttfts, 50)) if ttfts
            else None,
            "ttft_p95_s": float(np.percentile(ttfts, 95)) if ttfts
            else None,
            "queue_depth_mean": (float(np.mean(self.queue_depth_samples))
                                 if self.queue_depth_samples else 0.0),
            "queue_depth_max": (int(max(self.queue_depth_samples))
                                if self.queue_depth_samples else 0),
            "slot_occupancy_mean": (float(np.mean(self.occupancy_samples))
                                    if self.occupancy_samples else 0.0),
            "n_slots": self.n_slots,
            "kv_blocks": {"total": self.allocator.n_blocks,
                          "block_size": self.block_size,
                          "peak_in_use": self.allocator.peak_in_use},
            "decode_executables": self.decode_executables,
            "kv_donation_ok": self.donation_ok,
            "phase_gating": {
                "enabled": (self.plan_service is None
                            and self._phase_tables["prefill"] is not None),
                "phase_switches": self.phase_switches,
                "phase_steps": dict(self.phase_steps),
            },
            "decode_step_breakdown": self._step_breakdown(),
        }
        return {"requests": reqs, "aggregate": agg,
                "adaptive": self._adaptive_telemetry()}

    def _step_breakdown(self) -> dict:
        """Where the per-step host budget goes: device dispatch (token
        select + step call + bookkeeping), blocking host fetches
        (tokens / first-logits at retire), and telemetry sampling.
        Pipelined engines overlap the fetch of step t with the compute
        of step t+1, so fetch time here is host *blocked* time, not
        device time."""
        n = max(1, self.steps)
        return {
            "steps": self.steps,
            "pipelined": self._pipelined,
            "dispatch_s": round(self.dispatch_s, 6),
            "host_fetch_s": round(self.host_fetch_s, 6),
            "telemetry_s": round(self.telemetry_s, 6),
            "dispatch_ms_per_step": round(1e3 * self.dispatch_s / n, 4),
            "host_fetch_ms_per_step": round(1e3 * self.host_fetch_s / n,
                                            4),
            "telemetry_ms_per_step": round(1e3 * self.telemetry_s / n,
                                           4),
        }

    def _adaptive_telemetry(self) -> dict | None:
        """The telemetry()["adaptive"] block: bucket transitions, plan
        swaps + latency stats, the core's variant-cache state, and the
        plan service's per-bucket hit/flip counters.  None when the
        engine runs a frozen plan."""
        if self.plan_service is None:
            return None
        lat = self.swap_latencies_s
        return {
            "bucket_transitions": self.bucket_transitions,
            "plan_swaps": self.plan_swaps,
            "swap_latency_s": {
                "count": len(lat),
                "mean": float(np.mean(lat)) if lat else None,
                "max": float(max(lat)) if lat else None,
                "total": float(sum(lat)),
            },
            "plan_variants": self.core.plan_variants,
            "plan_evictions": self.core.plan_evictions,
            "active_plan_digest": (self._plan.digest
                                   if self._plan is not None else None),
            "service": self.plan_service.telemetry(),
        }


# --- synthetic open-loop traffic ------------------------------------------


def synthetic_requests(cfg, n: int, seed: int = 0,
                       prompt_len: tuple[int, int] = (4, 12),
                       new_tokens: tuple[int, int] = (4, 16),
                       temperature: float = 0.0) -> list[Request]:
    """Seeded ragged request set (uniform prompt/output length ranges,
    inclusive) — the reproducible workload behind `launch.serve
    --requests` and the traffic benchmark."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        p = int(rng.randint(prompt_len[0], prompt_len[1] + 1))
        m = int(rng.randint(new_tokens[0], new_tokens[1] + 1))
        shape = ((p, cfg.audio.n_codebooks) if cfg.family == "audio"
                 else (p,))
        prompt = rng.randint(0, cfg.vocab, size=shape).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=m,
                            temperature=temperature))
    return reqs


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> list[float]:
    """Open-loop Poisson arrival offsets (seconds): exponential
    inter-arrivals at `rate` req/s.  rate <= 0 means all-at-once."""
    if rate <= 0:
        return [0.0] * n
    rng = np.random.RandomState(seed)
    return list(np.cumsum(rng.exponential(1.0 / rate, size=n)))
