"""Training substrate: loop, checkpointing, fault tolerance."""
from . import checkpoint, fault_tolerance, loop
from .loop import TrainResult, make_train_step, train

__all__ = ["checkpoint", "fault_tolerance", "loop", "train",
           "make_train_step", "TrainResult"]
