"""Training loop: jit'd step with microbatch gradient accumulation,
checkpoint/auto-resume, straggler watchdog, failure injection.

`make_train_step` builds the pjit-able step used both by the real loop
and by the multi-pod dry-run (launch/dryrun.py lowers exactly this fn).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from ..data.pipeline import DataConfig, DataIterator
from ..models import init as model_init
from ..models import loss_fn
from ..optim import linear_warmup_cosine, make_optimizer
from . import checkpoint as ckpt
from .fault_tolerance import FailureInjector, StragglerWatchdog


def make_train_step(cfg: ModelConfig, rc: RunConfig,
                    total_steps: int = 10_000) -> Callable:
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    With rc.microbatches > 1 the batch's leading dim is split and
    gradients accumulate across a lax.scan (memory-bound shapes train with
    a fraction of the activation footprint).
    """
    _, opt_update = make_optimizer(rc.optimizer, rc.weight_decay)

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg, rc)
        return loss, aux, grads

    def step_fn(params, opt_state, batch, step):
        if rc.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = rc.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb_batch):
                g_acc, l_acc = carry
                loss, aux, grads = grads_of(params, mb_batch)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, jnp.float32(0)), micro,
                unroll=rc.microbatches if rc.scan_unroll > 0 else 1)
            grads = jax.tree.map(lambda g: g / rc.microbatches, grads)
            loss = loss / rc.microbatches
        else:
            loss, aux, grads = grads_of(params, batch)

        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        clip = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * clip, grads)

        lr = linear_warmup_cosine(step, rc.learning_rate,
                                  rc.warmup_steps, total_steps)
        params, opt_state = opt_update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "gnorm": gnorm, "lr": lr}

    return step_fn


@dataclasses.dataclass
class TrainResult:
    params: Any
    opt_state: Any
    losses: list
    resumed_from: int | None
    straggler_steps: list


def train(cfg: ModelConfig, rc: RunConfig, data_cfg: DataConfig,
          n_steps: int, *, seed: int = 0, ckpt_dir: str | None = None,
          ckpt_every: int = 0, injector: FailureInjector | None = None,
          params=None, opt_state=None) -> TrainResult:
    """Single-host training driver with auto-resume.

    If `ckpt_dir` holds a complete checkpoint, training resumes from it
    (params, optimizer state, data cursor) — the crash-recovery path used
    by the fault-tolerance integration test.
    """
    opt_init, _ = make_optimizer(rc.optimizer, rc.weight_decay)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model_init(key, cfg)
    if opt_state is None:
        opt_state = opt_init(params)

    start_step = 0
    resumed_from = None
    if ckpt_dir is not None:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), extra = ckpt.restore(
                ckpt_dir, last, (params, opt_state))
            start_step = last
            resumed_from = last

    nb = cfg.audio.n_codebooks if cfg.family == "audio" else 0
    it = DataIterator(data_cfg, start_step=start_step, n_codebooks=nb)
    step_fn = jax.jit(make_train_step(cfg, rc, total_steps=n_steps))
    watchdog = StragglerWatchdog()

    losses, stragglers = [], []
    for step in range(start_step, n_steps):
        if injector is not None:
            injector.check(step)
        batch = next(it)
        watchdog.step_start()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.int32(step))
        jax.block_until_ready(metrics["loss"])
        if watchdog.step_end():
            stragglers.append(step)
        losses.append(float(metrics["loss"]))
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, (params, opt_state),
                      extra={"data": it.state()})
            ckpt.gc_old(ckpt_dir)
    return TrainResult(params, opt_state, losses, resumed_from, stragglers)
