"""Fault-tolerance runtime: straggler watchdog, failure simulation hooks,
and elastic re-meshing policy.

On a real multi-pod deployment these hooks sit around the train loop:
  * `StragglerWatchdog` flags steps slower than `threshold` x the rolling
    median — the scheduler can then exclude the slow host and trigger an
    elastic re-mesh.
  * `plan_elastic_mesh` recomputes the largest (data, model)-consistent
    mesh from the surviving device count; checkpoint.restore_resharded
    re-places the state onto it.  Training resumes from the last complete
    manifest with the deterministic data pipeline skipped ahead.
"""
from __future__ import annotations

import dataclasses
import statistics
import time


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 2.0      # x median step time
    window: int = 32
    _times: list = dataclasses.field(default_factory=list)
    _t0: float | None = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self) -> bool:
        """Record a step; returns True if the step was a straggler."""
        dt = time.monotonic() - self._t0
        straggler = False
        if len(self._times) >= 8:
            med = statistics.median(self._times[-self.window:])
            straggler = dt > self.threshold * med
        self._times.append(dt)
        del self._times[:-self.window]
        return straggler

    @property
    def median(self) -> float:
        return statistics.median(self._times) if self._times else 0.0


def plan_elastic_mesh(n_devices: int, model_parallel: int
                      ) -> tuple[int, int]:
    """Largest (data, model) mesh from surviving devices.

    Keeps model_parallel fixed (parameters are sharded that way on disk);
    drops data-parallel replicas to the largest multiple that fits.  A
    512-chip job losing one 8-chip host re-meshes 63x... -> (63*8/model).
    """
    assert n_devices >= model_parallel, (n_devices, model_parallel)
    data = n_devices // model_parallel
    return data, model_parallel


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure simulation for integration tests."""
    fail_at_steps: tuple = ()

    def check(self, step: int):
        if step in self.fail_at_steps:
            raise RuntimeError(f"injected node failure at step {step}")
