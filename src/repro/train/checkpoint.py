"""Sharded checkpointing with atomic manifests (fault tolerance core).

Layout:
  <dir>/step_000123/
    manifest.json            # tree structure, shapes, dtypes, step, status
    shard_<host>.npz         # this host's param/opt shards (addressable)

Protocol: write shards -> fsync -> write manifest last (atomic rename).
A checkpoint without a manifest is incomplete and ignored on restore, so
a crash mid-save can never corrupt the restore path.  `latest_step` +
`restore` implement auto-resume; `restore_resharded` reloads onto a
different device count (elastic scaling after node loss).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import ml_dtypes
import numpy as np

# numpy .npz cannot store bfloat16 natively; round-trip via a uint16 view
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _to_storable(arr: np.ndarray) -> np.ndarray:
    return arr.view(np.uint16) if arr.dtype == _BF16 else arr


def _from_storable(arr: np.ndarray, target_dtype) -> np.ndarray:
    td = np.dtype(target_dtype)
    if td == _BF16 and arr.dtype == np.uint16:
        return arr.view(_BF16)
    return arr.astype(td)


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx)
                       for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree, host_id: int = 0,
         extra: dict | None = None) -> str:
    """Save this host's (addressable) shards of `tree` at `step`."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)
    leaves, _ = _flatten_with_paths(tree)
    arrays = {k: _to_storable(np.asarray(jax.device_get(v)))
              for k, v in leaves.items()}
    shard_path = os.path.join(step_dir, f"shard_{host_id:05d}.npz")
    tmp = shard_path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, shard_path)

    # manifest last (commit point) — only host 0 writes it
    if host_id == 0:
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "extra": extra or {},
            "status": "complete",
        }
        mtmp = os.path.join(step_dir, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mtmp, os.path.join(step_dir, "manifest.json"))
    return step_dir


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step with a complete manifest (incomplete saves skipped)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if not name.startswith("step_"):
            continue
        if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, host_id: int = 0):
    """Restore `like_tree`-structured arrays saved at `step`."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["status"] == "complete"
    shard = np.load(os.path.join(step_dir, f"shard_{host_id:05d}.npz"))
    leaves, treedef = _flatten_with_paths(like_tree)
    restored = {}
    for k, proto in leaves.items():
        arr = shard[k]
        assert list(arr.shape) == list(proto.shape), (k, arr.shape,
                                                      proto.shape)
        restored[k] = _from_storable(arr, proto.dtype)
    flat = [restored[k] for k in leaves.keys()]
    paths = list(leaves.keys())
    # rebuild in treedef order
    ordered = [restored[p] for p in paths]
    return jax.tree_util.tree_unflatten(
        treedef, ordered), manifest.get("extra", {})


def restore_resharded(ckpt_dir: str, step: int, like_tree,
                      put_fn=None, host_id: int = 0):
    """Elastic restore: load full arrays then re-place with `put_fn`
    (e.g. jax.device_put with the new mesh's shardings)."""
    tree, extra = restore(ckpt_dir, step, like_tree, host_id)
    if put_fn is not None:
        tree = put_fn(tree)
    return tree, extra


def gc_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(s for s in (
        int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
        if n.startswith("step_")))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
