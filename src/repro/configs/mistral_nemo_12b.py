"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import MISTRAL_NEMO_12B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
