"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import LLAMA3_2_VISION_90B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
