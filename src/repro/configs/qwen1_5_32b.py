"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import QWEN1_5_32B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
