"""Model / shape / run configuration dataclasses.

Every assigned architecture is a `ModelConfig`; the four assigned input
shapes are `ShapeConfig`s.  `reduced()` produces the CPU smoke-test variant
of any architecture (same family & wiring, tiny sizes).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    expert_d_ff: int = 0            # per-expert FFN width
    shared_d_ff: int = 0            # shared-expert FFN width
    every_n_layers: int = 1         # MoE FFN every N layers (1 = all)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128              # mamba2 N (per-head state)
    d_conv: int = 4
    headdim: int = 64
    expand: int = 2
    chunk: int = 256                # SSD chunk length
    n_groups: int = 1               # B/C groups

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    cross_attn_every: int = 5       # a cross-attn layer every N layers
    n_image_tokens: int = 1601      # precomputed patch-embedding stub
    image_d_model: int = 0          # 0 => same as text d_model


@dataclasses.dataclass(frozen=True)
class AudioConfig:
    n_codebooks: int = 4            # EnCodec parallel codebooks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention pattern
    attn_every: int = 1             # hybrid: attention every N layers
    sliding_window: int = 0         # 0 = full attention; >0 = local window
    # sub-configs
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    vision: VisionConfig | None = None
    audio: AudioConfig | None = None
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(1, self.n_heads))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts (long_500k shape)?"""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, h = self.d_model, self.head_dim()
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn = (self.n_layers // self.attn_every
                  if self.attn_every > 1 else self.n_layers)
        if self.family == "ssm":
            n_attn = 0
        attn = (d * self.n_heads * h + 2 * d * self.n_kv_heads * h
                + self.n_heads * h * d)
        per_layer += 0  # accumulated per kind below
        total = emb + n_attn * attn
        # FFN / experts
        if self.moe:
            moe_layers = self.n_layers // self.moe.every_n_layers
            dense_layers = self.n_layers - moe_layers
            total += moe_layers * (
                self.moe.n_experts * 3 * d * self.moe.expert_d_ff
                + (3 * d * self.moe.shared_d_ff
                   if self.moe.n_shared_experts else 0)
                + d * self.moe.n_experts)
            total += dense_layers * 3 * d * self.d_ff
        elif self.family == "ssm":
            pass
        else:
            total += self.n_layers * 3 * d * self.d_ff
        # ssm mixers
        if self.ssm:
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_ssm_heads(d)
            ssm_layers = (self.n_layers if self.family == "ssm"
                          else self.n_layers - n_attn)
            per = (d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state
                        + nh)              # in_proj (z,x,B,C,dt)
                   + di * self.ssm.d_conv  # conv
                   + nh                    # A
                   + di * d)               # out_proj
            total += ssm_layers * per
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        moe_layers = self.n_layers // self.moe.every_n_layers
        inactive = moe_layers * (self.moe.n_experts - self.moe.top_k) \
            * 3 * d * self.moe.expert_d_ff
        return int(self.param_count() - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {s.name: s for s in
                                  (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                   LONG_500K)}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving runtime knobs."""
    optimizer: str = "adamw"          # "adamw" | "adafactor"
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    microbatches: int = 1             # gradient accumulation
    remat: bool = True                # activation checkpoint each block
    fsdp: bool = True                 # shard params/optstate over data axis
    grad_compress: bool = False       # int8 error-feedback all-reduce
    kv_cache_dtype: str = "bfloat16"  # "int8" for quantized cache
    attn_impl: str = "flash_jnp"      # "flash_jnp" | "naive" | "pallas"
    attn_chunk: int = 1024            # kv chunk for flash_jnp / decode
    scan_unroll: int = 0              # layer-scan unroll factor (dry-run:
                                      # XLA counts a while-loop body once,
                                      # so the roofline pass compiles two
                                      # partial unrolls and extrapolates)
    # --- beyond-paper perf knobs (EXPERIMENTS.md §Perf) ---
    shard_heads: bool = False         # with_sharding_constraint heads->TP
    shard_attn: str = ""              # "heads" | "seq" (context parallel)
    sp_residual: bool = False         # Megatron-SP: residual stream stays
                                      # sequence-sharded between blocks
    batch_axes: str = "data"          # mesh axes carrying batch ("pod,data"
                                      # for multi-pod) used by constraints
    shard_loss: bool = False          # constrain logits + sharded-vocab
                                      # masked-sum loss (no fp32 gather)
    gqa_einsum: bool = False          # grouped-query einsums (no repeat)
    block_causal: bool = False        # triangular-chunk flash attention
    attn_q_chunk: int = 4096          # q-chunk for block-causal
    remat_policy: str = "nothing"     # "nothing" | "dots"


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    n_layers = min(cfg.n_layers, 2)
    kw: dict = dict(
        name=cfg.name + "-smoke", d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 2,
        d_ff=128, vocab=256, d_head=16)
    if cfg.family == "hybrid":
        kw["attn_every"] = 2
        n_layers = 4
    if cfg.family == "vlm":
        n_layers = 4
    kw["n_layers"] = n_layers
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2), expert_d_ff=64,
            shared_d_ff=64 if cfg.moe.n_shared_experts else 0)
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, headdim=16,
                                        chunk=32)
    if cfg.vision:
        kw["vision"] = dataclasses.replace(cfg.vision, n_image_tokens=8,
                                           cross_attn_every=2)
    if cfg.sliding_window:
        kw["sliding_window"] = 64
    return dataclasses.replace(cfg, **kw)
