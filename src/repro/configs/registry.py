"""The 10 assigned architectures (exact configs from the assignment) plus
the paper's own evaluation workloads.

Sources in brackets per the assignment table; all configs verbatim.
"""
from __future__ import annotations

from .base import (AudioConfig, ModelConfig, MoEConfig, SSMConfig,
                   VisionConfig)

# --- LM-family transformers -------------------------------------------------

QWEN2_7B = ModelConfig(
    name="qwen2-7b", family="dense", n_layers=28, d_model=3584, n_heads=28,
    n_kv_heads=4, d_ff=18944, vocab=152064, qkv_bias=True)
# [arXiv:2407.10671; hf]

QWEN1_5_32B = ModelConfig(
    name="qwen1.5-32b", family="dense", n_layers=64, d_model=5120,
    n_heads=40, n_kv_heads=40, d_ff=27392, vocab=152064, qkv_bias=True)
# [hf:Qwen/Qwen1.5; hf]

MISTRAL_NEMO_12B = ModelConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=131072, d_head=128)
# [hf:mistralai/Mistral-Nemo-Base-2407; hf] — 128k ctx

MINITRON_4B = ModelConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=9216, vocab=256000, d_head=128)
# [arXiv:2407.14679; hf] — pruned nemotron

MUSICGEN_LARGE = ModelConfig(
    name="musicgen-large", family="audio", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=32, d_ff=8192, vocab=2048,
    audio=AudioConfig(n_codebooks=4))
# [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens (frontend stub)

QWEN2_MOE_A2_7B = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=151936, qkv_bias=True,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4,
                  expert_d_ff=1408, shared_d_ff=4 * 1408))
# [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] — 4 shared + 60 routed top-4

LLAMA4_SCOUT_17B_A16E = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048, d_head=128,
    sliding_window=8192,    # chunked/local attention => sub-quadratic
    moe=MoEConfig(n_experts=16, top_k=1, n_shared_experts=1,
                  expert_d_ff=8192, shared_d_ff=8192))
# [hf:meta-llama/Llama-4-Scout-17B-16E; unverified] — MoE, early fusion

MAMBA2_780M = ModelConfig(
    name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, headdim=64, expand=2))
# [arXiv:2405.21060; unverified] — SSD (state-space duality), attn-free

LLAMA3_2_VISION_90B = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm", n_layers=100, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=28672, vocab=128256, d_head=128,
    vision=VisionConfig(cross_attn_every=5, n_image_tokens=1601))
# [hf:meta-llama/Llama-3.2-Vision; unverified] — cross-attn image layers

JAMBA_1_5_LARGE_398B = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72,
    d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    d_head=128, attn_every=8,     # Mamba : attention = 7 : 1
    moe=MoEConfig(n_experts=16, top_k=2, expert_d_ff=24576,
                  every_n_layers=2),
    ssm=SSMConfig(d_state=128, headdim=64, expand=2))
# [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave, MoE 16e top-2

ARCHS: dict[str, ModelConfig] = {c.name: c for c in (
    QWEN2_7B, QWEN1_5_32B, MISTRAL_NEMO_12B, MINITRON_4B, MUSICGEN_LARGE,
    QWEN2_MOE_A2_7B, LLAMA4_SCOUT_17B_A16E, MAMBA2_780M,
    LLAMA3_2_VISION_90B, JAMBA_1_5_LARGE_398B)}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; have {sorted(ARCHS)}")
    return ARCHS[name]
