"""Architecture configs: one module per assigned arch + shared registry."""
from .base import (LONG_500K, DECODE_32K, PREFILL_32K, SHAPES, TRAIN_4K,
                   AudioConfig, ModelConfig, MoEConfig, RunConfig,
                   ShapeConfig, SSMConfig, VisionConfig, reduced)
from .registry import ARCHS, get

__all__ = ["ARCHS", "get", "ModelConfig", "ShapeConfig", "RunConfig",
           "MoEConfig", "SSMConfig", "VisionConfig", "AudioConfig",
           "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "reduced"]
