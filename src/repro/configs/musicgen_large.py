"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import MUSICGEN_LARGE as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
