"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import MINITRON_4B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
