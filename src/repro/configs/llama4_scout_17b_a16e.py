"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import LLAMA4_SCOUT_17B_A16E as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
