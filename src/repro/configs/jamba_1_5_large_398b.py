"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import JAMBA_1_5_LARGE_398B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
