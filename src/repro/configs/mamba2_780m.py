"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import MAMBA2_780M as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
