"""Per-arch config module (assignment deliverable f): exposes CONFIG."""
from .registry import QWEN2_7B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)
