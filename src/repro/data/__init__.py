"""Deterministic sharded data pipeline."""
from .pipeline import DataConfig, DataIterator, batch_at_step, data_config_for

__all__ = ["DataConfig", "DataIterator", "batch_at_step", "data_config_for"]
