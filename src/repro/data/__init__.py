"""Deterministic sharded data pipeline for the training loop.

`DataIterator` yields batches that are a pure function of (config, step),
so a restarted or re-sharded job replays exactly the same token stream —
`batch_at_step` reconstructs any batch without iterating from zero."""
from .pipeline import DataConfig, DataIterator, batch_at_step, data_config_for

__all__ = ["DataConfig", "DataIterator", "batch_at_step", "data_config_for"]
