"""Deterministic synthetic token pipeline, sharded per host, with O(1)
skip-ahead (fault-tolerant resume: the pipeline is a pure function of
(seed, step, host), so restarting at step N replays nothing).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    seq_len: int = 1024
    global_batch: int = 8
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def batch_at_step(cfg: DataConfig, step: int,
                  n_codebooks: int = 0) -> dict:
    """Materialize the (deterministic) batch for `step` on this host.

    Tokens follow a mixture of repeated n-gram patterns so tiny models can
    measurably learn (loss decreases) in integration tests.
    """
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step),
        cfg.host_id)
    b, l = cfg.host_batch, cfg.seq_len
    shape = (b, l + 1) if not n_codebooks else (b, l + 1, n_codebooks)
    k1, k2 = jax.random.split(key)
    # structured stream: x[t+1] = (x[t] * 5 + phase) % vocab with noise
    start = jax.random.randint(k1, shape[:1] + shape[2:], 0, cfg.vocab)
    steps = jnp.arange(l + 1)

    def roll(s):
        def f(x, _):
            nxt = (x * 5 + 17) % cfg.vocab
            return nxt, x
        _, seq = jax.lax.scan(f, s, steps)
        return seq
    seq = jax.vmap(roll)(start)                     # (b, l+1, ...)
    if n_codebooks:
        seq = jnp.moveaxis(seq, 1, 1)               # already (b,l+1,nb)
    noise = jax.random.bernoulli(k2, 0.05, seq.shape)
    rnd = jax.random.randint(k2, seq.shape, 0, cfg.vocab)
    seq = jnp.where(noise, rnd, seq)
    return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}


class DataIterator:
    """Stateful wrapper with checkpointable cursor."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 n_codebooks: int = 0):
        self.cfg = cfg
        self.step = start_step
        self.n_codebooks = n_codebooks

    def __next__(self):
        b = batch_at_step(self.cfg, self.step, self.n_codebooks)
        self.step += 1
        return b

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict, n_codebooks: int = 0):
        assert state["seed"] == cfg.seed, "seed mismatch on resume"
        return cls(cfg, start_step=state["step"], n_codebooks=n_codebooks)


def data_config_for(model: ModelConfig, shape: ShapeConfig,
                    n_hosts: int = 1, host_id: int = 0) -> DataConfig:
    return DataConfig(vocab=model.vocab, seq_len=shape.seq_len,
                      global_batch=shape.global_batch, n_hosts=n_hosts,
                      host_id=host_id)
