"""Shared model layers, pure-functional JAX (no flax dependency).

Every layer is an (init, apply) pair over plain dict pytrees so that
sharding rules can match on parameter path names.

`linear` is the single pluggable projection execution layer: every dense
projection matmul in the model stack routes through it with a GEMM label,
and a jit-static `KernelPlanTable` (repro.quant.plan_table) decides per
label whether the projection lowers to the weight-stationary INT8 Pallas
kernel or the standard XLA matmul — the What/When/Where verdicts applied
as the deployed dataflow, not just telemetry.
"""
from __future__ import annotations

import contextlib
import math
import os
import sys
import threading
from functools import partial

import jax
import jax.numpy as jnp

from ..quant.int8 import dequant_contract, planned_linear
from ..quant.lowbit import (dequant_contract_fp8, dequant_contract_int4,
                            planned_linear_fp8, planned_linear_int4)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --- the planner-gated projection execution layer ---------------------------

_ROUTE_TRACE = threading.local()    # .records, per-thread: concurrent
                                    # sessions may trace simultaneously

# route strings linear() records (serving/dryrun/bench key off these)
CIM_ROUTE = "cim-int8-pallas"
DEQUANT_ROUTE = "int8-dequant-xla"
CIM_INT4_ROUTE = "cim-int4-pallas"
DEQUANT_INT4_ROUTE = "int4-dequant-xla"
CIM_FP8_ROUTE = "cim-fp8-pallas"
DEQUANT_FP8_ROUTE = "fp8-dequant-xla"
FLOAT_ROUTE = "xla"


@contextlib.contextmanager
def route_trace():
    """Collect every `linear` routing decision made while tracing.

    `linear` runs at Python trace time, so wrapping `jax.eval_shape` (or
    any jit trace) of a model function yields the *executed* route per
    projection label without any compute — this backs
    `ServeSession.route_report`, the dry-run routing block, and the
    label-coverage test.  Yields a list of
    {"label", "route", "callsite"} records.
    """
    prev = getattr(_ROUTE_TRACE, "records", None)
    _ROUTE_TRACE.records = []
    try:
        yield _ROUTE_TRACE.records
    finally:
        _ROUTE_TRACE.records = prev


def _record_route(label: str, route: str) -> None:
    records = getattr(_ROUTE_TRACE, "records", None)
    if records is not None:
        f = sys._getframe(2)        # the frame that called linear()
        records.append({
            "label": label, "route": route,
            "callsite": f"{os.path.basename(f.f_code.co_filename)}"
                        f":{f.f_lineno}"})


def linear(w, x, label: str, plan=None, spec: str | None = None):
    """y = x @ w — THE projection entry point, routed by the kernel plan.

    w is either a float weight array or a quantized {"q", "scale"} leaf
    (repro.quant.quantize_model_params).  With a KernelPlanTable `plan`,
    a quantized 2-D projection whose label gates on lowers to the
    weight-stationary INT8 Pallas kernel (planned_linear); everything
    else contracts against the raw int8 weight in x.dtype with the
    per-output-channel scale fused into the output epilogue
    (dequant_contract) — no per-step weight materialization.
    `spec` is an optional einsum spec for batched weights (MoE experts
    `"ecd,edf->ecf"`, audio lm_head `"bld,ndv->blnv"`); the Pallas path
    only applies to plain 2-D matmuls.

    The plan lookup happens at trace time (plan is jit-static), so the
    lowered program contains exactly one implementation per label — no
    runtime branch, no retrace.  Unknown labels raise KeyError from the
    plan table: model-side label drift must not silently disable gating.
    """
    quantized = isinstance(w, dict)
    use_cim = bool(plan is not None and quantized and plan.use_cim(label))
    if quantized:
        # the present key is the jit-static format discriminator
        # (quant.lowbit): "q" int8 / "q4" packed int4 / "qf8" scaled fp8
        if "q4" in w:
            if use_cim and spec is None and w["q4"].ndim == 2:
                _record_route(label, CIM_INT4_ROUTE)
                return planned_linear_int4(x, w["q4"], w["scale"])
            _record_route(label, DEQUANT_INT4_ROUTE)
            return dequant_contract_int4(x, w["q4"], w["scale"], spec)
        if "qf8" in w:
            if use_cim and spec is None and w["qf8"].ndim == 2:
                _record_route(label, CIM_FP8_ROUTE)
                return planned_linear_fp8(x, w["qf8"], w["scale"])
            _record_route(label, DEQUANT_FP8_ROUTE)
            return dequant_contract_fp8(x, w["qf8"], w["scale"], spec)
        if use_cim and spec is None and w["q"].ndim == 2:
            _record_route(label, CIM_ROUTE)
            return planned_linear(x, w["q"], w["scale"], use_cim_path=True)
        _record_route(label, DEQUANT_ROUTE)
        return dequant_contract(x, w["q"], w["scale"], spec)
    _record_route(label, FLOAT_ROUTE)
    if w.dtype != x.dtype:
        w = w.astype(x.dtype)
    return jnp.einsum(spec, x, w) if spec else x @ w


# --- initializers -----------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# --- norms -------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# --- rotary embeddings --------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x, positions, theta: float = 1e6):
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (d_head/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (.., s, d/2)
    cos = jnp.cos(ang)[..., :, None, :]                     # (.., s, 1, d/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# --- MLPs ----------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype)}


def swiglu(params, x, plan=None, label_prefix: str = "mlp"):
    """Gated MLP; label_prefix distinguishes dense "mlp-*" from the MoE
    "shared-*" expert (matching gemms_of_model labels)."""
    g = jax.nn.silu(linear(params["w_gate"], x, f"{label_prefix}-gate",
                           plan))
    u = linear(params["w_up"], x, f"{label_prefix}-up", plan)
    return linear(params["w_down"], g * u, f"{label_prefix}-down", plan)


# --- attention projections ------------------------------------------------------

def attn_init(key, d: int, n_heads: int, n_kv: int, d_head: int, dtype,
              qkv_bias: bool):
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, n_heads * d_head, dtype),
         "wk": dense_init(ks[1], d, n_kv * d_head, dtype),
         "wv": dense_init(ks[2], d, n_kv * d_head, dtype),
         "wo": dense_init(ks[3], n_heads * d_head, d, dtype,
                          scale=1.0 / math.sqrt(n_heads * d_head))}
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
    return p


def qkv_proj(params, x, n_heads: int, n_kv: int, d_head: int, plan=None):
    b, s, _ = x.shape
    q, k, v = (linear(params[w], x, lab, plan)
               for w, lab in (("wq", "Wq"), ("wk", "Wk"), ("wv", "Wv")))
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(b, s, n_heads, d_head),
            k.reshape(b, s, n_kv, d_head),
            v.reshape(b, s, n_kv, d_head))


def attn_out_proj(params, o, plan=None, label: str = "Wo"):
    """Attention output projection (self-attn "Wo" / cross "xattn-out"),
    shared by the full-sequence forward and the decode step so each label
    has exactly one linear call site."""
    return linear(params["wo"], o, label, plan)


# --- misc -----------------------------------------------------------------------

def unstack_tree(tree, i):
    """Select layer i from a stacked (scanned) parameter tree."""
    return jax.tree.map(lambda x: x[i], tree)


def stack_trees(trees):
    """Stack per-layer param trees into scan-ready arrays."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def init_stacked(key, n: int, init_fn):
    """vmap an init function over layer indices (fast stacked init)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
