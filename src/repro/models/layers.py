"""Shared model layers, pure-functional JAX (no flax dependency).

Every layer is an (init, apply) pair over plain dict pytrees so that
sharding rules can match on parameter path names.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --- initializers -----------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# --- norms -------------------------------------------------------------------

def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dt)


# --- rotary embeddings --------------------------------------------------------

def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x, positions, theta: float = 1e6):
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (d_head/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (.., s, d/2)
    cos = jnp.cos(ang)[..., :, None, :]                     # (.., s, 1, d/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# --- MLPs ----------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype)}


def swiglu(params, x):
    g = jax.nn.silu(x @ params["w_gate"])
    return (g * (x @ params["w_up"])) @ params["w_down"]


# --- attention projections ------------------------------------------------------

def attn_init(key, d: int, n_heads: int, n_kv: int, d_head: int, dtype,
              qkv_bias: bool):
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, n_heads * d_head, dtype),
         "wk": dense_init(ks[1], d, n_kv * d_head, dtype),
         "wv": dense_init(ks[2], d, n_kv * d_head, dtype),
         "wo": dense_init(ks[3], n_heads * d_head, d, dtype,
                          scale=1.0 / math.sqrt(n_heads * d_head))}
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
    return p


def qkv_proj(params, x, n_heads: int, n_kv: int, d_head: int):
    b, s, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return (q.reshape(b, s, n_heads, d_head),
            k.reshape(b, s, n_kv, d_head),
            v.reshape(b, s, n_kv, d_head))


# --- misc -----------------------------------------------------------------------

def unstack_tree(tree, i):
    """Select layer i from a stacked (scanned) parameter tree."""
    return jax.tree.map(lambda x: x[i], tree)


def stack_trees(trees):
    """Stack per-layer param trees into scan-ready arrays."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def init_stacked(key, n: int, init_fn):
    """vmap an init function over layer indices (fast stacked init)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
