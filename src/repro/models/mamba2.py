"""Mamba2 SSD (state-space duality) mixer in pure JAX (arXiv:2405.21060).

Chunked SSD algorithm: within a chunk the recurrence is computed in its
"dual" quadratic-attention form; across chunks a lax.scan carries the
(heads, headdim, d_state) recurrent state.  Decode is the O(1) recurrent
update — this is what makes long_500k serving linear for SSM archs.

Layout conventions:
  x     : (b, l, h, p)      p = headdim
  dt, A : (b, l, h)         per-head scalar decay (A negative)
  B, C  : (b, l, g, n)      n = d_state, g = groups (broadcast over heads)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSMConfig
from .layers import dense_init, linear


def segsum(x):
    """Stable 'segment sum' producing the lower-triangular decay matrix:
    out[i, j] = sum_{k=j+1..i} x[k] for i >= j, -inf otherwise."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """Chunked SSD scan.  Returns (y, final_state).

    x: (b, l, h, p); dt: (b, l, h) (softplus-ed); A: (h,) negative;
    B, C: (b, l, g, n) with h % g == 0.
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    nc = l // chunk
    assert nc * chunk == l, (l, chunk)
    rep = h // g

    # fold dt into x and A (discretization)
    a = A[None, None, :] * dt                     # (b, l, h)  log-decay
    xb = x * dt[..., None]                        # input scaled by dt

    # chunk everything: (b, nc, cl, ...)
    def ch(t):
        return t.reshape(b, nc, chunk, *t.shape[2:])
    xc, ac, Bc, Cc = ch(xb), ch(a), ch(B), ch(C)
    Bh = jnp.repeat(Bc, rep, axis=3)              # (b, nc, cl, h, n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    a_cum = jnp.cumsum(ac, axis=2)                # (b, nc, cl, h)
    # --- intra-chunk (dual quadratic form) ---
    L = jnp.exp(segsum(ac.transpose(0, 1, 3, 2)))     # (b, nc, h, cl, cl)
    scores = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh)  # (b,nc,h,cl,cl)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores * L, xc)

    # --- chunk states ---
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)    # (b,nc,cl,h)
    states = jnp.einsum("bcihn,bcih,bcihp->bchnp",
                        Bh, decay_to_end, xc)               # (b,nc,h,n,p)

    # --- inter-chunk recurrence over nc ---
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])               # (b, nc, h)

    def step(carry, inp):
        st, dec = inp                                        # (b,h,n,p),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                    # emit incoming

    s0 = (init_state.astype(jnp.float32) if init_state is not None
          else jnp.zeros((b, h, n, p), jnp.float32))
    final, prev_states = jax.lax.scan(
        step, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                 # (b,nc,h,n,p)

    # --- contribution of carried state to each position ---
    state_decay = jnp.exp(a_cum)                             # (b,nc,cl,h)
    y_off = jnp.einsum("bcihn,bchnp,bcih->bcihp",
                       Ch, prev_states, state_decay)
    y = (y_diag + y_off).astype(jnp.float32).reshape(b, l, h, p)
    return y, final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """O(1) recurrent update for one token.

    state: (b, h, n, p); x_t: (b, h, p); dt_t: (b, h);
    B_t, C_t: (b, g, n).  Returns (y_t, new_state)."""
    h = x_t.shape[1]
    rep = h // B_t.shape[1]
    Bh = jnp.repeat(B_t, rep, axis=1)            # (b, h, n)
    Ch = jnp.repeat(C_t, rep, axis=1)
    decay = jnp.exp(A[None, :] * dt_t)           # (b, h)
    add = jnp.einsum("bhn,bhp->bhnp", Bh, x_t * dt_t[..., None])
    new_state = state * decay[..., None, None] + add
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y, new_state


# --- full mixer (in_proj -> conv -> SSD -> gate -> out_proj) -----------------

def mamba_init(key, cfg: ModelConfig, dtype):
    """Projections are separate named weights (not one fused in_proj) so
    tensor-parallel sharding aligns with segment boundaries (z/x/dt shard
    over heads; the small B/C group projections replicate)."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_ssm_heads(d)
    gdim = s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    return {
        "w_z": dense_init(ks[0], d, di, dtype),
        "w_x": dense_init(ks[1], d, di, dtype),
        "w_B": dense_init(ks[2], d, gdim, dtype),
        "w_C": dense_init(ks[3], d, gdim, dtype),
        "w_dt": dense_init(ks[4], d, nh, dtype),
        "conv_x": (jax.random.normal(ks[5], (s.d_conv, di),
                                     jnp.float32) * 0.02).astype(dtype),
        "conv_B": jnp.full((s.d_conv, gdim), 0.02, dtype),
        "conv_C": jnp.full((s.d_conv, gdim), 0.02, dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),   # A = -exp(A_log) in [-1,0)
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[0], di, d, dtype),
    }


def _causal_conv(xBC, w, carry=None):
    """Depthwise causal conv over (b, l, c) with kernel (k, c).

    carry: (b, k-1, c) previous context (decode) or None (train: zero pad).
    Returns (y, new_carry)."""
    k = w.shape[0]
    b, l, c = xBC.shape
    pad = (carry if carry is not None
           else jnp.zeros((b, k - 1, c), xBC.dtype))
    xp = jnp.concatenate([pad, xBC], axis=1)
    # sum_k w[k] * x[t - (K-1) + k]
    y = sum(xp[:, i:i + l, :] * w[i] for i in range(k))
    new_carry = xp[:, -(k - 1):, :] if k > 1 else None
    return jax.nn.silu(y), new_carry


def mamba_apply(params, x, cfg: ModelConfig, state=None, conv_carry=None,
                decode: bool = False, plan=None):
    """x: (b, l, d).  Train/prefill when decode=False (l = seq);
    decode=True expects l == 1 and a (state, conv_carry) cache.
    Returns (y, (new_state, new_conv_carry))."""
    s = cfg.ssm
    b, l, d = x.shape
    di = s.d_inner(d)
    gdim = s.n_groups * s.d_state
    nh = s.n_ssm_heads(d)
    z = linear(params["w_z"], x, "ssm-z", plan)
    xs = linear(params["w_x"], x, "ssm-x", plan)
    # B/C/dt are one fused GEMM in the planner's taxonomy ("ssm-BCdt"):
    # three weights, one verdict, one call site
    B, C, dt = (linear(params[w], x, "ssm-BCdt", plan)
                for w in ("w_B", "w_C", "w_dt"))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])              # (b, l, nh)
    A = -jnp.exp(params["A_log"])                          # (nh,)

    # depthwise causal conv on x / B / C separately (carry is concat)
    if conv_carry is not None:
        cx, cB, cC = (conv_carry[..., :di],
                      conv_carry[..., di:di + gdim],
                      conv_carry[..., di + gdim:])
    else:
        cx = cB = cC = None
    xs, nx = _causal_conv(xs, params["conv_x"], cx)
    B, nB = _causal_conv(B, params["conv_B"], cB)
    C, nC = _causal_conv(C, params["conv_C"], cC)
    new_conv = (jnp.concatenate([nx, nB, nC], axis=-1)
                if nx is not None else None)
    p = s.headdim
    xh = xs.reshape(b, l, nh, p)
    Bh = B.reshape(b, l, s.n_groups, s.d_state)
    Ch = C.reshape(b, l, s.n_groups, s.d_state)

    if decode:
        y_t, new_state = ssd_decode_step(
            state, xh[:, 0], dt[:, 0], A, Bh[:, 0], Ch[:, 0])
        y = y_t[:, None]                                   # (b, 1, nh, p)
    else:
        y, new_state = ssd_chunked(xh, dt, A, Bh, Ch,
                                   chunk=min(s.chunk, l), init_state=state)
    y = y + xh * params["D"][None, None, :, None]
    y = y.reshape(b, l, di)
    # gated RMSNorm (mamba2 norm-before-gate)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf ** 2, -1, keepdims=True)
                            + cfg.rmsnorm_eps)
    y = (yf * params["norm_scale"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return linear(params["out_proj"], y, "ssm-out", plan), \
        (new_state, new_conv)


def mamba_cache_shapes(cfg: ModelConfig, batch: int):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_ssm_heads(cfg.d_model)
    gdim = s.n_groups * s.d_state
    return ((batch, nh, s.d_state, s.headdim),            # ssm state
            (batch, s.d_conv - 1, di + 2 * gdim))          # conv carry
