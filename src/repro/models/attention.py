"""Attention implementations: naive, chunked-flash (pure JAX, memory-safe
for 32k prefill), decode with KV cache, and sliding-window (sub-quadratic).

The Pallas TPU kernels in repro.kernels implement the same contracts; the
`impl` switch selects between them (dry-run/CPU uses the jnp versions).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_expand(k, n_heads: int):
    """(b, s, kv, d) -> (b, s, H, d) by repeating kv heads."""
    b, s, kv, d = k.shape
    if kv == n_heads:
        return k
    rep = n_heads // kv
    return jnp.repeat(k, rep, axis=2)


def naive_causal(q, k, v, positions_q=None, positions_k=None,
                 window: int = 0):
    """Reference attention.  q: (b, sq, H, d); k/v: (b, sk, KV, d)."""
    b, sq, nh, d = q.shape
    k = _gqa_expand(k, nh)
    v = _gqa_expand(v, nh)
    sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    pos_q = (positions_q if positions_q is not None
             else jnp.arange(sq)[None, :] + (sk - sq))
    pos_k = (positions_k if positions_k is not None
             else jnp.arange(sk)[None, :])
    mask = pos_q[:, None, :, None] >= pos_k[:, None, None, :]
    if window:
        mask &= pos_q[:, None, :, None] - pos_k[:, None, None, :] < window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_jnp(q, k, v, chunk: int = 1024, window: int = 0,
              unroll: bool = False):
    """Chunked online-softmax causal attention in pure JAX.

    O(sq * chunk) live memory per head — lowers cleanly for 32k prefill
    where the naive score matrix would not fit.  Streams KV chunks with a
    lax.scan carrying (m, l, acc) online-softmax state.
    """
    b, sq, nh, d = q.shape
    k = _gqa_expand(k, nh)
    v = _gqa_expand(v, nh)
    sk = k.shape[1]
    n_chunks = sk // chunk
    assert n_chunks * chunk == sk, (sk, chunk)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    # operands stay in their input dtype; the MXU accumulates in f32
    # (preferred_element_type) — halves gather/reshard bytes vs upcasting
    qf = q
    kc = k.reshape(b, n_chunks, chunk, nh, d)
    vc = v.reshape(b, n_chunks, chunk, nh, d)
    pos_q = jnp.arange(sq) + (sk - sq)

    def step(carry, inputs):
        m, l, acc = carry
        kj, vj, j = inputs
        pos_k = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = pos_q[None, None, :, None] >= pos_k[None, None, None, :]
        if window:
            mask &= (pos_q[None, None, :, None]
                     - pos_k[None, None, None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, nh, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nh, sq), jnp.float32)
    a0 = jnp.zeros((b, nh, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)),
        unroll=(n_chunks if unroll else 1))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)   # (b, sq, H, d)


def decode_attend(q, k_cache, v_cache, cache_len, chunk: int = 0,
                  window: int = 0, grouped: bool = False):
    """Single-token decode attention over a (b, S, KV, d) cache.

    cache_len: (b,) valid lengths.  q: (b, 1, H, d).  Linear in S.

    grouped=True uses grouped-query einsums that never materialize the
    GQA-expanded cache: with a sequence-sharded cache this keeps every
    large tensor S-sharded, so the only collectives are the tiny partial
    softmax/output reductions (flash-decoding via GSPMD) — instead of the
    full-cache all-gather the jnp.repeat formulation forces.
    """
    b, _, nh, d = q.shape
    S = k_cache.shape[1]
    kv = k_cache.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    pos = jnp.arange(S)[None, :]
    valid = pos < cache_len[:, None]
    if window:
        valid &= pos >= (cache_len[:, None] - window)
    if grouped:
        rep = nh // kv
        qg = q.reshape(b, 1, kv, rep, d).astype(jnp.float32)
        s = jnp.einsum("bqgrd,bsgd->bgrqs", qg,
                       k_cache.astype(jnp.float32)) * scale
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bgrqs,bsgd->bqgrd", p,
                         v_cache.astype(jnp.float32))
        return out.reshape(b, 1, nh, d).astype(q.dtype)
    k = _gqa_expand(k_cache, nh)
    v = _gqa_expand(v_cache, nh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale      # (b, H, 1, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_block_causal(q, k, v, q_chunk: int = 4096, kv_chunk: int = 1024,
                       window: int = 0, unroll: bool = False):
    """Block-causal chunked attention: queries are processed in chunks and
    each chunk only visits KV chunks at or below its diagonal — halves the
    attention FLOPs vs scanning every KV chunk (and skips far-past chunks
    entirely under a sliding window)."""
    b, sq, nh, d = q.shape
    sk = k.shape[1]
    assert sq == sk, "block-causal path expects self-attention"
    nq = sq // q_chunk
    if nq * q_chunk != sq or nq <= 1:
        return flash_jnp(q, k, v, chunk=kv_chunk, window=window,
                         unroll=unroll)
    outs = []
    for qi in range(nq):
        qs = qi * q_chunk
        kv_end = qs + q_chunk
        kv_start = 0
        if window:
            kv_start = max(0, (qs - window) // kv_chunk * kv_chunk)
        qcb = q[:, qs:qs + q_chunk]
        kcb = k[:, kv_start:kv_end]
        vcb = v[:, kv_start:kv_end]
        outs.append(flash_jnp(qcb, kcb, vcb,
                              chunk=min(kv_chunk, kv_end - kv_start),
                              window=window, unroll=unroll))
    return jnp.concatenate(outs, axis=1)


def attend(q, k, v, impl: str = "flash_jnp", chunk: int = 1024,
           window: int = 0, unroll: bool = False, block_causal: bool = False,
           q_chunk: int = 4096):
    if impl == "naive" or k.shape[1] % max(chunk, 1) != 0 \
            or k.shape[1] <= chunk:
        return naive_causal(q, k, v, window=window)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True, window=window)
    if block_causal:
        return flash_block_causal(q, k, v, q_chunk=q_chunk, kv_chunk=chunk,
                                  window=window, unroll=unroll)
    return flash_jnp(q, k, v, chunk=chunk, window=window, unroll=unroll)
