"""Model zoo: unified LM covering all 10 assigned architectures."""
from . import attention, layers, mamba2, model, moe
from .layers import linear, route_trace
from .model import (decode_step, forward, init, init_cache,
                    init_paged_cache, loss_fn, n_periods, period_slots)

__all__ = ["init", "forward", "loss_fn", "decode_step", "init_cache",
           "init_paged_cache", "period_slots", "n_periods", "linear",
           "route_trace", "attention", "layers", "mamba2", "model", "moe"]
