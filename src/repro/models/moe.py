"""Mixture-of-Experts FFN with scatter/gather dispatch (no dense one-hot
einsum — keeps HLO FLOPs close to useful FLOPs, which matters for the
roofline's MODEL_FLOPS / HLO_FLOPS ratio).

Dispatch: top-k routing -> position-in-expert via cumsum -> scatter tokens
into an (E, C, d) buffer -> batched expert matmuls -> weighted gather-back.
Tokens beyond expert capacity are dropped (standard capacity-factor MoE).
Under EP the (E, C, d) buffer is sharded on E over the model axis and the
scatter/gather lower to all-to-alls.

Decode exception: when the token count fits expert capacity (T <= C —
always true for a decode micro-batch) capacity dropping is impossible,
so `moe_apply` skips the dispatch machinery and runs every expert over
every token with a plain batched einsum, then selects each token's
top-k outputs.  Same math (the fast-path FLOP count E*T rows is <= the
buffer's E*C), far fewer ops on the hot path — the scatter/cumsum/
segment-sum chain is the dominant per-step cost at decode shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, linear, swiglu, swiglu_init


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, m.n_experts, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (m.n_experts, d, m.expert_d_ff),
                                     jnp.float32) / d ** 0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (m.n_experts, d, m.expert_d_ff),
                                   jnp.float32) / d ** 0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (m.n_experts, m.expert_d_ff, d),
                                     jnp.float32)
                   / m.expert_d_ff ** 0.5).astype(dtype),
    }
    if m.n_shared_experts:
        p["shared"] = swiglu_init(ks[4], d, m.shared_d_ff, dtype)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)      # round up to 8


def moe_apply(params, x, cfg: ModelConfig, plan=None, *,
              force_buffered: bool = False):
    """x: (b, l, d) -> (y, aux_loss).

    `force_buffered` disables the T <= C decode fast path so the parity
    test can pin both dispatch forms to the same output."""
    m = cfg.moe
    b, l, d = x.shape
    T = b * l
    xt = x.reshape(T, d)
    C = capacity(cfg, T)

    # router stays an f32 ungated matmul: it is not in the GEMM taxonomy
    # (tiny, and routing stability dominates any kernel choice)
    logits = (xt @ params["router"]).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)    # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if T <= C and not force_buffered:
        # decode / micro-batch fast path: an expert can receive at most
        # T <= C assignments (a token's top-k experts are distinct), so
        # capacity dropping is IMPOSSIBLE and the scatter/gather
        # dispatch machinery below is pure overhead — at decode shapes
        # it costs more host+device dispatch than the compute it
        # avoids.  Run every expert over every token outright (E*T rows
        # vs the buffer's E*C, T <= C) and select each token's top-k
        # outputs.  The per-(expert, token) dot products and the
        # k-ascending weighted sum are the same contractions in the
        # same order as the buffered path: identical semantics, fewer
        # ops.
        g = jax.nn.silu(linear(params["w_gate"], xt, "expert-gate",
                               plan, spec="td,edf->etf"))
        u = linear(params["w_up"], xt, "expert-up", plan,
                   spec="td,edf->etf")
        eout = linear(params["w_down"], g * u, "expert-down", plan,
                      spec="etf,efd->etd")          # (E, T, d)
        sel = jnp.take_along_axis(eout.transpose(1, 0, 2),
                                  expert_ids[:, :, None], axis=1)
        yt = (sel * gate_vals[:, :, None].astype(x.dtype)).sum(axis=1)
    else:
        # position of each (token, k) assignment within its expert
        flat_ids = expert_ids.reshape(-1)                    # (T*k,)
        onehot = jax.nn.one_hot(flat_ids, m.n_experts, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1                 # (T*k, E)
        pos_in_expert = jnp.take_along_axis(
            pos, flat_ids[:, None], axis=1)[:, 0]            # (T*k,)
        keep = pos_in_expert < C

        # scatter tokens into (E, C, d)
        tok_idx = jnp.repeat(jnp.arange(T), m.top_k)
        buf = jnp.zeros((m.n_experts, C, d), x.dtype)
        safe_pos = jnp.where(keep, pos_in_expert, C - 1)
        contrib = jnp.where(keep[:, None], xt[tok_idx], 0)
        buf = buf.at[flat_ids, safe_pos].add(contrib)

        # batched expert SwiGLU.  Expert weights are (E, d, f): the
        # planner's verdict gates dequantization routing, but the
        # batched-expert einsum has no 2-D weight-stationary form, so a
        # gated expert label executes as an int8-dequant XLA
        # contraction (recorded as such by route_trace)
        g = jax.nn.silu(linear(params["w_gate"], buf, "expert-gate",
                               plan, spec="ecd,edf->ecf"))
        u = linear(params["w_up"], buf, "expert-up", plan,
                   spec="ecd,edf->ecf")
        eout = linear(params["w_down"], g * u, "expert-down", plan,
                      spec="ecf,efd->ecd")

        # gather back with routing weights
        back = eout[flat_ids, safe_pos]                      # (T*k, d)
        w = (gate_vals.reshape(-1) * keep).astype(x.dtype)
        yt = jax.ops.segment_sum(back * w[:, None], tok_idx,
                                 num_segments=T)
    y = yt.reshape(b, l, d)

    if m.n_shared_experts:
        y = y + swiglu(params["shared"], x, plan, label_prefix="shared")

    # load-balancing aux loss (Switch-style)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], m.n_experts, dtype=jnp.float32),
        axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.n_experts * jnp.sum(frac_tokens * frac_probs) \
        * m.router_aux_loss
    return y, aux
