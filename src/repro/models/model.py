"""Unified LM model covering all 10 assigned architectures.

A model is a stack of *periods*: the smallest repeating layer pattern.
Each period is a list of *slots*, each slot = (mixer, ffn) where
mixer ∈ {attn, mamba, cross} and ffn ∈ {dense, moe, None}.  Parameters for
slot s are stacked over periods, so the layer stack lowers to one
lax.scan over periods (small HLO, fast compile, remat-friendly):

  dense / moe / audio : period = [(attn, dense|moe)]
  ssm (mamba2)        : period = [(mamba, None)]
  hybrid (jamba)      : period = [(attn, ffn0), (mamba, ffn1) x 7],
                        ffn_i = moe on odd global layer indices
  vlm (llama3.2-v)    : period = [(attn, dense) x 4, (cross, dense)]

Entry points:
  init(key, cfg)                       -> params
  forward(params, batch, cfg, rc)      -> logits / loss   (train, prefill)
  init_cache(cfg, rc, batch, max_len)  -> cache pytree
  decode_step(params, cache, tok, pos) -> logits, cache   (serving)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, RunConfig
from .attention import attend, decode_attend
from .layers import (attn_init, attn_out_proj, apply_rope, dtype_of,
                     embed_init, linear, qkv_proj, rmsnorm, rmsnorm_init,
                     swiglu, swiglu_init)
from .mamba2 import (mamba_apply, mamba_cache_shapes, mamba_init)
from .moe import moe_apply, moe_init


def _batch_axes(rc: RunConfig):
    axes = tuple(a for a in rc.batch_axes.split(",") if a)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


@dataclasses.dataclass(frozen=True)
class Slot:
    mixer: str          # "attn" | "mamba" | "cross"
    ffn: str | None     # "dense" | "moe" | None


def period_slots(cfg: ModelConfig) -> list[Slot]:
    if cfg.family in ("dense", "audio"):
        return [Slot("attn", "dense")]
    if cfg.family == "moe":
        return [Slot("attn", "moe")]
    if cfg.family == "ssm":
        return [Slot("mamba", None)]
    if cfg.family == "hybrid":
        slots = []
        for i in range(cfg.attn_every):
            mixer = "attn" if i == 0 else "mamba"
            ffn = "moe" if (cfg.moe and i % cfg.moe.every_n_layers
                            == cfg.moe.every_n_layers - 1) else "dense"
            slots.append(Slot(mixer, ffn))
        return slots
    if cfg.family == "vlm":
        ce = cfg.vision.cross_attn_every
        return [Slot("attn", "dense")] * (ce - 1) + [Slot("cross", "dense")]
    raise ValueError(cfg.family)


def n_periods(cfg: ModelConfig) -> int:
    P = len(period_slots(cfg))
    assert cfg.n_layers % P == 0, (cfg.n_layers, P)
    return cfg.n_layers // P


# --- init --------------------------------------------------------------------

def _slot_init(key, slot: Slot, cfg: ModelConfig, dtype):
    km, kf, kn1, kn2 = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if slot.mixer in ("attn", "cross"):
        p["attn"] = attn_init(km, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim(), dtype, cfg.qkv_bias)
    else:
        p["mamba"] = mamba_init(km, cfg, dtype)
    if slot.ffn is not None:
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if slot.ffn == "dense":
            p["mlp"] = swiglu_init(kf, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["moe"] = moe_init(kf, cfg, dtype)
    return p


def init(key, cfg: ModelConfig):
    dtype = dtype_of(cfg.param_dtype)
    slots = period_slots(cfg)
    np_ = n_periods(cfg)
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    params: dict[str, Any] = {}
    if cfg.family == "audio":
        nb = cfg.audio.n_codebooks
        keys = jax.random.split(k_emb, nb)
        params["embed"] = jnp.stack(
            [embed_init(k, cfg.vocab, cfg.d_model, dtype) for k in keys])
        params["lm_head"] = jnp.stack(
            [embed_init(k, cfg.vocab, cfg.d_model, dtype).T
             for k in jax.random.split(k_head, nb)])
    else:
        params["embed"] = embed_init(k_emb, cfg.vocab, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(
                k_head, cfg.vocab, cfg.d_model, dtype).T
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)

    # stacked per-slot params over periods
    slot_keys = jax.random.split(k_layers, len(slots))
    stacked = []
    for si, slot in enumerate(slots):
        pkeys = jax.random.split(slot_keys[si], np_)
        per = [_slot_init(k, slot, cfg, dtype) for k in pkeys]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    params["slots"] = stacked
    return params


# --- forward (train / prefill) --------------------------------------------------

def _cross_q_proj(sp, h, b, l, nh, dh, plan=None):
    """Cross-attention query projection ("xattn-Q"), shared by the
    full-sequence forward and the decode step."""
    return linear(sp["attn"]["wq"], h, "xattn-Q", plan).reshape(
        b, l, nh, dh)


def _lm_logits(params, x, cfg: ModelConfig, plan=None):
    """LM head ("lm_head"), shared by forward and decode.  Audio heads are
    per-codebook (nb, d, vocab) and contract via einsum; tied embeddings
    reuse the (float) embedding matrix transposed."""
    spec = "bld,ndv->blnv" if cfg.family == "audio" else None
    head = (params["embed"].T
            if cfg.tie_embeddings and cfg.family != "audio"
            else params["lm_head"])
    return linear(head, x, "lm_head", plan, spec=spec)


def _apply_mixer_full(slot: Slot, sp, x, cfg: ModelConfig, rc: RunConfig,
                      image_kv=None, return_cache=False, plan=None):
    """Full-sequence mixer.  Returns (y, cache_entry_or_None)."""
    h = rmsnorm(sp["norm1"], x, cfg.rmsnorm_eps)
    if slot.mixer == "mamba":
        y, (st, cv) = mamba_apply(sp["mamba"], h, cfg, plan=plan)
        return y, ((st, cv) if return_cache else None)
    nh, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim()
    if slot.mixer == "cross":
        b, l, _ = x.shape
        q = _cross_q_proj(sp, h, b, l, nh, dh, plan)
        kimg, vimg = image_kv
        # bidirectional attention onto image tokens (no mask)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       _expand(kimg, nh).astype(jnp.float32))
        s = s / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p,
                       _expand(vimg, nh).astype(jnp.float32))
        y = attn_out_proj(sp["attn"], o.astype(x.dtype).reshape(
            b, l, nh * dh), plan, label="xattn-out")
        return y, ((kimg, vimg) if return_cache else None)
    q, k, v = qkv_proj(sp["attn"], h, nh, kv, dh, plan)
    pos = jnp.arange(x.shape[1])[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    mode = rc.shard_attn or ("heads" if rc.shard_heads else "")
    if mode:
        # "heads": head-dim TP (GSPMD pads uneven head counts).
        # "seq": context parallelism — queries shard over sequence (always
        # mesh-divisible), K/V all-gather per layer (small for GQA).
        # Batch axes MUST be pinned: a None batch dim lets GSPMD replicate
        # the global batch (EXPERIMENTS.md §Perf iteration 4).
        from jax.sharding import PartitionSpec as _P
        ba = _batch_axes(rc)
        spec = (_P(ba, None, "model", None) if mode == "heads"
                else _P(ba, "model", None, None))
        q = jax.lax.with_sharding_constraint(q, spec)
        if mode == "heads":
            k = jax.lax.with_sharding_constraint(k, spec)
            v = jax.lax.with_sharding_constraint(v, spec)
        else:
            k = jax.lax.with_sharding_constraint(
                k, _P(ba, None, None, None))
            v = jax.lax.with_sharding_constraint(
                v, _P(ba, None, None, None))
    o = attend(q, k, v, impl=rc.attn_impl, chunk=rc.attn_chunk,
               window=cfg.sliding_window, unroll=rc.scan_unroll > 0,
               block_causal=rc.block_causal, q_chunk=rc.attn_q_chunk)
    b, l, _ = x.shape
    y = attn_out_proj(sp["attn"], o.reshape(b, l, nh * dh), plan)
    return y, ((k, v) if return_cache else None)


def _expand(t, nh):
    rep = nh // t.shape[2]
    return jnp.repeat(t, rep, axis=2) if rep > 1 else t


def _apply_ffn(slot: Slot, sp, x, cfg: ModelConfig, plan=None):
    if slot.ffn is None:
        return x, 0.0
    h = rmsnorm(sp["norm2"], x, cfg.rmsnorm_eps)
    if slot.ffn == "dense":
        return x + swiglu(sp["mlp"], h, plan), 0.0
    y, aux = moe_apply(sp["moe"], h, cfg, plan)
    return x + y, aux


def _project_image(params, cfg, image_embeds):
    """Precompute per-period cross-attn K/V from the image-embedding stub."""
    return image_embeds  # projected per-slot inside the scan


def forward(params, tokens, cfg: ModelConfig, rc: RunConfig,
            image_embeds=None, plan=None):
    """tokens: (b, l) int32, or (b, l, n_codebooks) for audio.
    Returns logits (b, l, vocab) (audio: (b, l, nb, vocab)).
    `plan` (KernelPlanTable, jit-static) gates quantized projections —
    prefill and decode share the same per-label verdicts."""
    slots = period_slots(cfg)
    if cfg.family == "audio":
        x = jnp.sum(jax.vmap(lambda e, t: e[t], in_axes=(0, 2),
                             out_axes=2)(params["embed"], tokens), axis=2)
    else:
        x = params["embed"][tokens]
    x = x.astype(dtype_of(cfg.compute_dtype))

    def _sp(t):
        if not rc.sp_residual:
            return t
        from jax.sharding import PartitionSpec as _P
        return jax.lax.with_sharding_constraint(
            t, _P(_batch_axes(rc), "model", None))

    def period_body(carry, period_params):
        x, aux = carry
        x = _sp(x)
        for si, slot in enumerate(slots):
            sp = period_params[si]
            ikv = None
            if slot.mixer == "cross":
                b, limg, _ = image_embeds.shape
                kvh, dh = cfg.n_kv_heads, cfg.head_dim()
                kimg, vimg = (
                    linear(sp["attn"][w], image_embeds, "xattn-KV", plan
                           ).reshape(b, limg, kvh, dh)
                    for w in ("wk", "wv"))
                ikv = (kimg, vimg)
            y, _ = _apply_mixer_full(slot, sp, x, cfg, rc, image_kv=ikv,
                                     plan=plan)
            x = _sp(x + y)
            x, a = _apply_ffn(slot, sp, x, cfg, plan)
            x = _sp(x)
            aux = aux + a
        return (x, aux), None

    body = period_body
    if rc.remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if rc.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(period_body, policy=policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               params["slots"],
                               unroll=max(1, min(rc.scan_unroll,
                                                 n_periods(cfg))))
    x = rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    return _lm_logits(params, x, cfg, plan), aux


def loss_fn(params, batch, cfg: ModelConfig, rc: RunConfig):
    """batch: {"tokens": ..., "targets": ..., ["image_embeds"]}.

    The gold logit uses a masked sum over the vocab axis instead of
    take_along_axis: identical numerics, but it keeps the reduction local
    to a vocab-sharded logits tensor (a sharded-dim gather makes GSPMD
    replicate the fp32 logits — tens of GB; §Perf iteration 4)."""
    logits, aux = forward(params, batch["tokens"], cfg, rc,
                          image_embeds=batch.get("image_embeds"))
    tgt = batch["targets"]
    if rc.shard_loss:
        from jax.sharding import PartitionSpec as _P
        ba = _batch_axes(rc)
        spec = (_P(ba, None, None, "model") if cfg.family == "audio"
                else _P(ba, None, "model"))
        logits = jax.lax.with_sharding_constraint(logits, spec)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    vocab_ids = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_ids == tgt[..., None], lf, 0.0),
                   axis=-1)
    ce = jnp.mean(lse - gold)
    return ce + aux, {"ce": ce, "aux": aux}


# --- KV / state caches -----------------------------------------------------------

def init_cache(cfg: ModelConfig, rc: RunConfig, batch: int, max_len: int,
               n_image_tokens: int = 0):
    """Cache pytree: one entry per slot, stacked over periods."""
    np_ = n_periods(cfg)
    kv_dtype = dtype_of(rc.kv_cache_dtype) if rc.kv_cache_dtype != "int8" \
        else jnp.int8
    dh, kvh = cfg.head_dim(), cfg.n_kv_heads
    caches = []
    for slot in period_slots(cfg):
        if slot.mixer == "attn":
            shape = (np_, batch, max_len, kvh, dh)
            caches.append({"k": jnp.zeros(shape, kv_dtype),
                           "v": jnp.zeros(shape, kv_dtype)})
            if rc.kv_cache_dtype == "int8":
                caches[-1]["k_scale"] = jnp.zeros(
                    (np_, batch, max_len, kvh), jnp.bfloat16)
                caches[-1]["v_scale"] = jnp.zeros(
                    (np_, batch, max_len, kvh), jnp.bfloat16)
        elif slot.mixer == "cross":
            shape = (np_, batch, n_image_tokens, kvh, dh)
            caches.append({"k": jnp.zeros(shape, jnp.bfloat16),
                           "v": jnp.zeros(shape, jnp.bfloat16)})
        else:
            sst, scv = mamba_cache_shapes(cfg, batch)
            caches.append({"state": jnp.zeros((np_,) + sst, jnp.float32),
                           "conv": jnp.zeros((np_,) + scv, jnp.bfloat16)})
    return caches


def init_paged_cache(cfg: ModelConfig, rc: RunConfig, n_slots: int,
                     n_blocks: int, block_size: int,
                     n_image_tokens: int = 0):
    """Block-pool KV cache for slot-scheduled continuous batching.

    Attention slots get a shared *pool* of `n_blocks` fixed-size blocks,
    (periods, n_blocks, block_size, kv_heads, head_dim), instead of one
    contiguous (batch, max_len) strip per request: each serving slot owns
    a host-managed list of physical block ids (its block table) and
    ragged request lengths share one jitted decode executable.  Mamba
    state / conv carries and cross-attn image KV stay per-slot (they are
    O(1) in sequence length, nothing to page)."""
    np_ = n_periods(cfg)
    kv_dtype = dtype_of(rc.kv_cache_dtype) if rc.kv_cache_dtype != "int8" \
        else jnp.int8
    dh, kvh = cfg.head_dim(), cfg.n_kv_heads
    caches = []
    for slot in period_slots(cfg):
        if slot.mixer == "attn":
            shape = (np_, n_blocks, block_size, kvh, dh)
            caches.append({"k": jnp.zeros(shape, kv_dtype),
                           "v": jnp.zeros(shape, kv_dtype)})
            if rc.kv_cache_dtype == "int8":
                caches[-1]["k_scale"] = jnp.zeros(
                    (np_, n_blocks, block_size, kvh), jnp.bfloat16)
                caches[-1]["v_scale"] = jnp.zeros(
                    (np_, n_blocks, block_size, kvh), jnp.bfloat16)
        elif slot.mixer == "cross":
            shape = (np_, n_slots, n_image_tokens, kvh, dh)
            caches.append({"k": jnp.zeros(shape, jnp.bfloat16),
                           "v": jnp.zeros(shape, jnp.bfloat16)})
        else:
            sst, scv = mamba_cache_shapes(cfg, n_slots)
            caches.append({"state": jnp.zeros((np_,) + sst, jnp.float32),
                           "conv": jnp.zeros((np_,) + scv, jnp.bfloat16)})
    return caches


def _quantize_kv(t):
    scale = jnp.max(jnp.abs(t), axis=-1, keepdims=True) / 127.0 + 1e-8
    return (jnp.round(t / scale).astype(jnp.int8),
            scale[..., 0].astype(jnp.bfloat16))


def _dequantize_kv(q, scale):
    return q.astype(jnp.bfloat16) * scale[..., None]


def _paged_write(pool, new, pos, block_tables, active):
    """Scatter one row per slot into a block pool.

    pool: (n_blocks, block_size, ...); new: (b, ...); pos: (b,) logical
    positions; block_tables: (b, max_blocks) physical block ids.
    Inactive slots write out-of-bounds and are dropped (their KV must not
    clobber live blocks)."""
    n_blocks, bs = pool.shape[0], pool.shape[1]
    blk = jnp.take_along_axis(block_tables, (pos // bs)[:, None],
                              axis=1)[:, 0]
    phys = blk * bs + pos % bs
    if active is not None:
        phys = jnp.where(active, phys, n_blocks * bs)     # OOB -> drop
    flat = pool.reshape((n_blocks * bs,) + pool.shape[2:])
    flat = flat.at[phys].set(new.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


def _paged_view(pool, block_tables):
    """Gather each slot's logical KV strip from the pool:
    (n_blocks, bs, ...) + (b, max_blocks) -> (b, max_blocks * bs, ...)."""
    v = pool[block_tables]
    return v.reshape((v.shape[0], v.shape[1] * v.shape[2]) + v.shape[3:])


def _mask_rows(new, old, active):
    """Per-slot select: active slots take the updated cache row, evicted /
    free slots keep (frozen) state so garbage tokens can't corrupt them."""
    if active is None:
        return new
    m = active.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new.astype(old.dtype), old)


# --- decode -----------------------------------------------------------------------

def decode_step(params, cache, tokens, pos, cfg: ModelConfig,
                rc: RunConfig, plan=None, active=None, block_tables=None):
    """One decode step.  tokens: (b, 1) (audio: (b, 1, nb)); pos: () int32
    current length (uniform across batch) OR (b,) int32 per-slot lengths
    (ragged, continuous batching).  Returns (logits, new_cache).
    `plan` is the jit-static KernelPlanTable: gated projection labels
    lower to the INT8 Pallas path inside the one compiled step.

    Continuous-batching extensions (all jit-dynamic — one executable):
      * ragged `pos` (b,): each slot attends/ropes at its own length;
      * `active` (b,) bool: cache writes of inactive (free / draining)
        slots are masked out, so join/evict never retraces or corrupts
        neighbouring requests;
      * `block_tables` (b, max_blocks) int32: attention KV lives in the
        block pool laid out by `init_paged_cache`; reads gather the
        slot's logical strip, writes scatter one row into its current
        block.  Required whenever `pos` is ragged and the arch has
        attention slots."""
    slots = period_slots(cfg)
    b = tokens.shape[0]
    ragged = jnp.ndim(pos) == 1
    if ragged and block_tables is None and any(s.mixer == "attn"
                                              for s in slots):
        raise ValueError(
            "ragged per-slot positions need a paged KV cache: pass "
            "block_tables (see init_paged_cache) for attention archs")
    if cfg.family == "audio":
        x = jnp.sum(jax.vmap(lambda e, t: e[t], in_axes=(0, 2),
                             out_axes=2)(params["embed"], tokens), axis=2)
    else:
        x = params["embed"][tokens]
    x = x.astype(dtype_of(cfg.compute_dtype))
    nh, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim()

    def period_body(x, scanned):
        period_params, period_cache = scanned
        new_cache = []
        for si, slot in enumerate(slots):
            sp, cache_s = period_params[si], period_cache[si]
            h = rmsnorm(sp["norm1"], x, cfg.rmsnorm_eps)
            if slot.mixer == "mamba":
                y, (st, cv) = mamba_apply(
                    sp["mamba"], h, cfg, state=cache_s["state"],
                    conv_carry=cache_s["conv"], decode=True, plan=plan)
                new_cache.append(
                    {"state": _mask_rows(st, cache_s["state"], active),
                     "conv": _mask_rows(cv, cache_s["conv"], active)})
            elif slot.mixer == "cross":
                q = _cross_q_proj(sp, h, b, 1, nh, dh, plan)
                o = decode_attend(
                    q, cache_s["k"], cache_s["v"],
                    jnp.full((b,), cache_s["k"].shape[1], jnp.int32))
                y = attn_out_proj(sp["attn"], o.reshape(b, 1, nh * dh),
                                  plan, label="xattn-out")
                new_cache.append(cache_s)
            else:
                q, k, v = qkv_proj(sp["attn"], h, nh, kvh, dh, plan)
                pvec = (pos[:, None] if ragged
                        else jnp.full((b, 1), pos, jnp.int32))
                q = apply_rope(q, pvec, cfg.rope_theta)
                k = apply_rope(k, pvec, cfg.rope_theta)
                if block_tables is not None:
                    # paged path: scatter this token's KV row into the
                    # slot's current block, then gather its logical strip
                    if rc.kv_cache_dtype == "int8":
                        kq, ks = _quantize_kv(k)
                        vq, vs = _quantize_kv(v)
                        ck = _paged_write(cache_s["k"], kq[:, 0], pos,
                                          block_tables, active)
                        cv = _paged_write(cache_s["v"], vq[:, 0], pos,
                                          block_tables, active)
                        cks = _paged_write(cache_s["k_scale"], ks[:, 0],
                                           pos, block_tables, active)
                        cvs = _paged_write(cache_s["v_scale"], vs[:, 0],
                                           pos, block_tables, active)
                        kd = _dequantize_kv(_paged_view(ck, block_tables),
                                            _paged_view(cks, block_tables))
                        vd = _dequantize_kv(_paged_view(cv, block_tables),
                                            _paged_view(cvs, block_tables))
                        new_cache.append({"k": ck, "v": cv,
                                          "k_scale": cks, "v_scale": cvs})
                    else:
                        ck = _paged_write(cache_s["k"], k[:, 0], pos,
                                          block_tables, active)
                        cv = _paged_write(cache_s["v"], v[:, 0], pos,
                                          block_tables, active)
                        kd = _paged_view(ck, block_tables)
                        vd = _paged_view(cv, block_tables)
                        new_cache.append({"k": ck, "v": cv})
                    lens = (pos + 1 if ragged
                            else jnp.full((b,), pos + 1, jnp.int32))
                    o = decode_attend(q, kd, vd, lens,
                                      window=cfg.sliding_window,
                                      grouped=rc.gqa_einsum)
                    y = attn_out_proj(sp["attn"],
                                      o.reshape(b, 1, nh * dh), plan)
                    x = x + y
                    x, _ = _apply_ffn(slot, sp, x, cfg, plan)
                    continue
                if rc.kv_cache_dtype == "int8":
                    kq, ks = _quantize_kv(k)
                    vq, vs = _quantize_kv(v)
                    ck = jax.lax.dynamic_update_slice_in_dim(
                        cache_s["k"], kq, pos, axis=1)
                    cv = jax.lax.dynamic_update_slice_in_dim(
                        cache_s["v"], vq, pos, axis=1)
                    cks = jax.lax.dynamic_update_slice_in_dim(
                        cache_s["k_scale"], ks, pos, axis=1)
                    cvs = jax.lax.dynamic_update_slice_in_dim(
                        cache_s["v_scale"], vs, pos, axis=1)
                    kd = _dequantize_kv(ck, cks)
                    vd = _dequantize_kv(cv, cvs)
                    new_cache.append({"k": ck, "v": cv, "k_scale": cks,
                                      "v_scale": cvs})
                else:
                    ck = jax.lax.dynamic_update_slice_in_dim(
                        cache_s["k"], k.astype(cache_s["k"].dtype), pos,
                        axis=1)
                    cv = jax.lax.dynamic_update_slice_in_dim(
                        cache_s["v"], v.astype(cache_s["v"].dtype), pos,
                        axis=1)
                    kd, vd = ck, cv
                    new_cache.append({"k": ck, "v": cv})
                lens = jnp.full((b,), pos + 1, jnp.int32)
                o = decode_attend(q, kd, vd, lens,
                                  window=cfg.sliding_window,
                                  grouped=rc.gqa_einsum)
                y = attn_out_proj(sp["attn"], o.reshape(b, 1, nh * dh),
                                  plan)
            x = x + y
            x, _ = _apply_ffn(slot, sp, x, cfg, plan)
        return x, new_cache

    # scan over periods, threading per-period cache slices
    x, new_caches = jax.lax.scan(
        period_body, x, (params["slots"], cache),
        unroll=max(1, min(rc.scan_unroll, n_periods(cfg))))
    x = rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    return _lm_logits(params, x, cfg, plan), new_caches
