"""Benchmark runner (deliverable d): one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV to stdout and writes full row CSVs
to results/bench/.
"""
from __future__ import annotations

import csv
import json
import os
import time

from . import paper_benches as P
from . import llm_planner_bench as L
from . import sweep_bench as S
from . import serve_gating_bench as G
from . import campaign_bench as C

BENCHES = [
    ("fig2_gemm_landscape", P.fig2_gemm_landscape),
    ("fig7_table2_mapping_vs_heuristic", P.fig7_table2_mapping_vs_heuristic),
    ("fig9_primitive_scatter", P.fig9_primitive_scatter),
    ("fig10_dimension_sweeps", P.fig10_dimension_sweeps),
    ("fig11_12_memory_levels", P.fig11_12_memory_levels),
    ("fig13_square_gemms", P.fig13_square_gemms),
    ("table6_workload_characteristics", P.table6_workload_characteristics),
    ("llm_planner_decisions", L.planner_decisions),
    ("planner_sweep_speed", S.planner_sweep_speed),
    ("campaign_speed", C.campaign_speed),
    ("serve_gating_speed", G.serve_gating_speed),
]


def main() -> None:
    outdir = os.path.join("results", "bench")
    os.makedirs(outdir, exist_ok=True)
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        t0 = time.perf_counter()
        rows, derived = fn()
        dt = time.perf_counter() - t0
        us = 1e6 * dt / max(1, len(rows))
        with open(os.path.join(outdir, f"{name}.csv"), "w", newline="") as f:
            if rows:
                w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
                w.writeheader()
                w.writerows(rows)
        with open(os.path.join(outdir, f"{name}.derived.json"), "w") as f:
            json.dump(derived, f, indent=1, default=str)
        print(f"{name},{us:.1f},{json.dumps(derived, default=str)!r}")


if __name__ == "__main__":
    main()
