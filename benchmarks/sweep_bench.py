"""Planner sweep-engine benchmark: batched vs scalar full-workload planning.

Times `plan_workload` over the FULL llm_workloads GEMM set (every assigned
arch x train_4k + decode_32k) through both backends and checks verdict
parity.  Three numbers matter:

  * scalar_s      — the original per-call Python path,
  * batched_s     — vectorized backend, warm jit, cold result cache
                    (steady-state planning of a never-seen workload),
  * cached_s      — vectorized backend, warm LRU cache (the serving
                    engine's repeat-query case).

Writes BENCH_planner.json (repo root by default; $BENCH_PLANNER_OUT
overrides) so CI tracks the trajectory PR over PR.

Run directly:  PYTHONPATH=src python -m benchmarks.sweep_bench
"""
from __future__ import annotations

import json
import os
import time

from repro.configs import ARCHS, SHAPES
from repro.core.llm_workloads import gemms_of_model
from repro.core.planner import plan_workload
from repro.core.sweep import cache_clear, cache_info


def full_llm_gemm_set():
    gemms = []
    for mc in ARCHS.values():
        for sname in ("train_4k", "decode_32k"):
            gemms += gemms_of_model(mc, SHAPES[sname])
    return gemms


def planner_sweep_speed(write_json: bool = True):
    gemms = full_llm_gemm_set()

    # start from a cold cache even when earlier benches warmed it:
    # otherwise the warm-up batch below shrinks to the uncached remainder
    # and the timed run pays the full-workload jit compile instead.
    cache_clear()
    t0 = time.perf_counter()
    plan_workload(gemms, backend="vectorized")
    cold_s = time.perf_counter() - t0          # includes jit compilation

    cache_clear()
    t0 = time.perf_counter()
    batched = plan_workload(gemms, backend="vectorized")
    batched_s = time.perf_counter() - t0       # warm jit, cold cache

    t0 = time.perf_counter()
    plan_workload(gemms, backend="vectorized")
    cached_s = time.perf_counter() - t0        # warm LRU cache

    t0 = time.perf_counter()
    scalar = plan_workload(gemms, backend="scalar")
    scalar_s = time.perf_counter() - t0

    mismatches = sum(
        a.use_cim != b.use_cim or a.best_energy != b.best_energy
        for a, b in zip(batched, scalar))

    derived = {
        "n_gemms": len(gemms),
        "scalar_s": round(scalar_s, 3),
        "batched_cold_jit_s": round(cold_s, 3),
        "batched_s": round(batched_s, 3),
        "cached_s": round(cached_s, 4),
        "speedup_x": round(scalar_s / batched_s, 1),
        "cached_speedup_x": round(scalar_s / cached_s, 1),
        "verdict_mismatches": mismatches,
        "cache": cache_info(),
    }
    rows = [{"backend": "scalar", "seconds": scalar_s},
            {"backend": "vectorized_cold_jit", "seconds": cold_s},
            {"backend": "vectorized", "seconds": batched_s},
            {"backend": "vectorized_cached", "seconds": cached_s}]
    if write_json:
        out = os.environ.get("BENCH_PLANNER_OUT", "BENCH_planner.json")
        with open(out, "w") as f:
            json.dump(derived, f, indent=1)
    return rows, derived


if __name__ == "__main__":
    _, derived = planner_sweep_speed()
    print(json.dumps(derived, indent=1))
