"""Planner sweep-engine benchmark: batched vs scalar full-workload planning.

Times `plan_workload` over the FULL llm_workloads GEMM set (every assigned
arch x train_4k + decode_32k) through both backends and checks verdict
parity.  The headline numbers:

  * scalar_s      — the original per-call Python path,
  * batched_s     — vectorized backend, warm jit, cold result cache
                    (steady-state planning of a never-seen workload),
  * cached_s      — vectorized backend, warm LRU cache (the serving
                    engine's repeat-query case),
  * greedy_*      — the same comparison under order_mode="greedy"
                    (per-row smallest-factor-outermost order selected
                    in-kernel — previously a scalar-only path),
  * sharded       — the whole batch row-sharded with shard_map over an
                    explicit >=1-device mesh (launch.mesh.row_mesh),
                    with a bitwise metrics-parity check against the
                    unsharded engine,
  * streamed      — the distributed engine's memory-bounded streaming
                    enumerator (SweepEngine(chunk_rows=...)): the grid
                    folds through the kernel in mesh-aligned tiles,
                    bitwise-parity-gated against the whole-batch engine;
                    the derived "distributed" block records tile counts
                    and jax.process_count() so a pod-scale run
                    (repro.launch.distributed) is self-describing,
  * pallas        — the fused hand-written sweep kernel
                    (repro.kernels.sweep_eval) as the planner backend,
                    verdict-parity-gated against the vectorized run, plus
                    a kernel-vs-kernel large-batch row (32k flattened
                    mapping rows through jitted evaluate_flat vs
                    sweep_eval) answering the ROADMAP's "does hand-written
                    Pallas beat XLA fusion at large batch".  The
                    pallas-not-slower sanity gate applies only where the
                    kernel compiles natively (mode == "compiled"); in CPU
                    interpret mode (CI) the timing is recorded for the
                    trajectory but slower-than-XLA is expected and not an
                    error.  Platforms without any Pallas lowering record
                    the fallback reason instead,
  * precision     — the full workload re-planned at INT4 and FP8 (the
                    widened What axis), vectorized timing plus a
                    pallas-vs-vectorized verdict-parity gate per
                    precision; recorded under the `precision` block
                    (campaign_bench's whole-file merge preserves it).

The cold measurement explicitly drops the compiled kernels first
(`sweep.jit_cache_clear` — every jitted variant, greedy and sharded
included, lives in one registry), so "cold_jit" means cold no matter what
ran earlier in the process (benchmarks/run.py runs other planner benches
before this one).  Scalar, warm and cached runs take the best of
`repeats` samples to shrug off transient machine contention, and the derived
output carries a `sanity_ok` flag asserting the expected
cold > warm > cached ordering plus provenance (git SHA, host,
timestamp) so a mismeasured run is self-describing rather than a silent
bogus regression.

Writes BENCH_planner.json (repo root by default; $BENCH_PLANNER_OUT
overrides) so CI tracks the trajectory PR over PR; a run failing any
gate (verdict parity — exact or greedy —, sharded parity, timing sanity)
is quarantined to *.failed instead so it can't replace the trusted
trajectory entry, and running this module directly (as CI does) then
exits nonzero.

Run directly:  PYTHONPATH=src python -m benchmarks.sweep_bench
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import time
from datetime import datetime, timezone

import jax
import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.core import GEMM
from repro.core.llm_workloads import gemms_of_model
from repro.core.planner import plan_workload, standard_configs
from repro.core.sweep import (SweepEngine, cache_clear, cache_info,
                              jit_cache_clear, plan_workload_batched)
from repro.core.vectorized import (MAP_FIELDS, config_row, enumerate_space,
                                   evaluate_flat, precision_row)
from repro.kernels.sweep_eval import pallas_status, sweep_eval
from repro.launch.mesh import row_mesh


def full_llm_gemm_set():
    gemms = []
    for mc in ARCHS.values():
        for sname in ("train_4k", "decode_32k"):
            gemms += gemms_of_model(mc, SHAPES[sname])
    return gemms


def _provenance() -> dict:
    try:
        # --dirty marks artifacts produced by uncommitted code: the bare
        # sha alone would claim a commit that cannot reproduce the run
        sha = subprocess.check_output(
            ["git", "describe", "--always", "--dirty"], text=True,
            stderr=subprocess.DEVNULL).strip()
    except Exception:
        sha = "unknown"
    return {"git_sha": sha,
            "host": socket.gethostname(),
            "timestamp_utc": datetime.now(timezone.utc)
            .strftime("%Y-%m-%dT%H:%M:%SZ"),
            "jax": jax.__version__,
            "device": jax.devices()[0].platform}


def _best_of(repeats: int, fn, setup=None):
    """(best wall time, last result) of `repeats` samples of fn()."""
    best, result = float("inf"), None
    for _ in range(repeats):
        if setup is not None:
            setup()
        t0 = time.perf_counter()
        result = fn()
        best = min(best, time.perf_counter() - t0)
    return best, result


LARGE_BATCH_ROWS = 32768


def _large_flat_batch(n_rows: int = LARGE_BATCH_ROWS):
    """One big flattened mapping batch (a full exhaustive-search-scale
    grid of one paper-scale GEMM on one config) for the kernel-vs-kernel
    large-batch timing row."""
    g = GEMM(4096, 4096, 4096)
    cfg = standard_configs()["Digital-6T@RF"]
    space = enumerate_space(g, cfg, max_points=n_rows)
    b = int(np.asarray(space["k_arr"]).shape[0])
    batch = {f: np.asarray(space[f], np.float32) for f in MAP_FIELDS}
    for name, v in {"M": g.M, "N": g.N, "K": g.K,
                    **precision_row(g), **config_row(cfg)}.items():
        batch[name] = np.full((b,), float(v), np.float32)
    return batch, b


def planner_sweep_speed(write_json: bool = True, repeats: int = 3):
    gemms = full_llm_gemm_set()

    # honest cold-jit: drop both the compiled kernels and the result
    # cache, so "cold" is cold even when earlier benches in this process
    # (run.py order) already traced the kernels or warmed the LRU.
    cache_clear()
    jit_cache_clear()
    t0 = time.perf_counter()
    plan_workload(gemms, backend="vectorized")
    cold_s = time.perf_counter() - t0          # includes jit compilation

    # best of `repeats` samples each, so a transient contention spike
    # can't record e.g. a warm run slower than cold
    batched_s, batched = _best_of(           # warm jit, cold result cache
        repeats, lambda: plan_workload(gemms, backend="vectorized"),
        setup=cache_clear)
    cached_s, _ = _best_of(                  # warm LRU cache
        repeats, lambda: plan_workload(gemms, backend="vectorized"))
    # snapshot now: the greedy runs below clear the result cache again,
    # and the artifact should record the warm-LRU hit/miss telemetry
    cache_after_cached = cache_info()
    scalar_s, scalar = _best_of(
        repeats, lambda: plan_workload(gemms, backend="scalar"))

    mismatches = sum(
        a.use_cim != b.use_cim or a.best_energy != b.best_energy
        for a, b in zip(batched, scalar))

    # --- greedy order mode: previously a silent scalar fallback, now an
    # in-kernel per-row order selection — track its speedup separately
    greedy_s, greedy = _best_of(
        repeats,
        lambda: plan_workload(gemms, order_mode="greedy",
                              backend="vectorized"),
        setup=cache_clear)
    greedy_scalar_s, greedy_scalar = _best_of(
        repeats,
        lambda: plan_workload(gemms, order_mode="greedy",
                              backend="scalar"))
    greedy_mismatches = sum(
        a.use_cim != b.use_cim or a.best_energy != b.best_energy
        for a, b in zip(greedy, greedy_scalar))

    # --- row-sharded evaluation over an explicit mesh of all local
    # devices (>= 1: a 1-device mesh still exercises the shard_map path);
    # parity is enforced bitwise on the chosen option's metrics against
    # an explicitly UNSHARDED engine — the default engine auto-shards on
    # multi-device accelerator hosts, so comparing against `batched`
    # there would check the sharded kernel against itself
    mesh = row_mesh(jax.devices())
    sharded_engine = SweepEngine(mesh=mesh)
    unsharded = plan_workload_batched(gemms, engine=SweepEngine(mesh=None))
    sharded_s, sharded = _best_of(
        repeats,
        lambda: plan_workload_batched(gemms, engine=sharded_engine),
        setup=sharded_engine.cache_clear)
    sharded_parity_ok = all(
        a.use_cim == b.use_cim and a.best_energy == b.best_energy
        and a.chosen.energy_pj == b.chosen.energy_pj
        and a.chosen.time_ns == b.chosen.time_ns
        for a, b in zip(sharded, unsharded))

    # --- streaming chunked evaluation: the distributed engine's
    # memory-bounded enumerator (repro.launch.distributed pairs it with a
    # multi-host mesh; here it runs on the local mesh so CI measures the
    # chunking overhead and gates bitwise parity — a pod run records its
    # process topology in the same block via jax.process_count())
    chunk_rows = 2048
    chunked_engine = SweepEngine(mesh=None, chunk_rows=chunk_rows)
    streamed_s, streamed = _best_of(
        repeats, lambda: plan_workload_batched(gemms, engine=chunked_engine),
        setup=chunked_engine.cache_clear)
    streamed_parity_ok = all(
        a.use_cim == b.use_cim and a.best_energy == b.best_energy
        and a.chosen.energy_pj == b.chosen.energy_pj
        and a.chosen.time_ns == b.chosen.time_ns
        for a, b in zip(streamed, unsharded))
    chunk_tel = chunked_engine.cache_info()["chunks"]

    # --- pallas backend: the fused sweep kernel as the planner path, with
    # verdict parity against the vectorized run and a kernel-vs-kernel
    # large-batch timing row (the ROADMAP's Pallas-vs-XLA-fusion question)
    status = pallas_status()
    pallas_s, pallas_plan = _best_of(
        repeats, lambda: plan_workload(gemms, backend="pallas"),
        setup=cache_clear)
    pallas_mismatches = sum(
        a.use_cim != b.use_cim or a.best_energy != b.best_energy
        for a, b in zip(pallas_plan, batched))

    if status["mode"] == "unavailable":
        # the planner path above already fell back to the XLA kernel; a
        # direct jit(sweep_eval) here would re-raise the lowering error
        # the probe caught — record the reason instead of crashing
        large_batch_block = {"skipped": status["reason"]}
        pallas_sanity_ok = True
        large_rows = []
    else:
        big_batch, big_rows = _large_flat_batch()
        xla_fn = jax.jit(evaluate_flat)
        pallas_fn = jax.jit(sweep_eval)
        for fn in (xla_fn, pallas_fn):              # warm the executables
            jax.block_until_ready(fn(big_batch)["energy_pj"])
        xla_large_s, _ = _best_of(
            repeats, lambda: jax.block_until_ready(
                xla_fn(big_batch)["energy_pj"]))
        pallas_large_s, _ = _best_of(
            repeats, lambda: jax.block_until_ready(
                pallas_fn(big_batch)["energy_pj"]))
        # slower-than-XLA is only an error where the kernel compiles
        # natively; interpret mode (CPU CI) records the ratio w/o gating
        pallas_sanity_ok = (status["mode"] != "compiled"
                            or pallas_large_s <= xla_large_s)
        if not pallas_sanity_ok:
            print(f"WARNING: compiled pallas sweep kernel slower than XLA "
                  f"fusion at {big_rows} rows ({pallas_large_s:.4f}s vs "
                  f"{xla_large_s:.4f}s) — hand-written kernel regression",
                  file=sys.stderr)
        large_batch_block = {
            "rows": big_rows,
            "xla_s": round(xla_large_s, 4),
            "pallas_s": round(pallas_large_s, 4),
            "pallas_speedup_x": round(xla_large_s / pallas_large_s, 2),
        }
        large_rows = [
            {"backend": f"xla_large_batch_{big_rows}rows",
             "seconds": round(xla_large_s, 4)},
            {"backend": f"pallas_large_batch_{big_rows}rows",
             "seconds": round(pallas_large_s, 4)}]

    # --- precision axis: the full workload re-planned at every non-default
    # precision of the widened What axis (INT4 packed weights, FP8
    # scaled), timed through the vectorized backend and parity-gated
    # against the pallas kernel — the same dual-backend gate the INT8
    # grid gets, so a precision-factor regression in either kernel is a
    # red bench, not a quiet drift
    precision_block = {}
    precision_parity_ok = True
    for tok, (p_bits, p_fp) in (("int4", (4, False)), ("fp8", (8, True))):
        pgemms = [g.scaled(bits=p_bits, fp=p_fp) for g in gemms]
        prec_s, prec_plan = _best_of(
            repeats, lambda: plan_workload(pgemms, backend="vectorized"),
            setup=cache_clear)
        prec_pallas = plan_workload(pgemms, backend="pallas")
        prec_mismatches = sum(
            a.use_cim != b.use_cim or a.best_energy != b.best_energy
            for a, b in zip(prec_plan, prec_pallas))
        precision_parity_ok &= prec_mismatches == 0
        precision_block[tok] = {
            "seconds": round(prec_s, 3),
            "pallas_verdict_mismatches": prec_mismatches,
            "cim_fraction": round(
                sum(d.use_cim for d in prec_plan) / len(prec_plan), 3),
        }

    sanity_ok = cold_s > batched_s > cached_s
    if not sanity_ok:
        print(f"WARNING: planner_sweep_speed ordering violated "
              f"(cold {cold_s:.3f}s, warm {batched_s:.3f}s, cached "
              f"{cached_s:.4f}s) — machine noisy, do not commit this run",
              file=sys.stderr)

    derived = {
        "n_gemms": len(gemms),
        "scalar_s": round(scalar_s, 3),
        "batched_cold_jit_s": round(cold_s, 3),
        "batched_s": round(batched_s, 3),
        "cached_s": round(cached_s, 4),
        "speedup_x": round(scalar_s / batched_s, 1),
        "cached_speedup_x": round(scalar_s / cached_s, 1),
        "verdict_mismatches": mismatches,
        "greedy_scalar_s": round(greedy_scalar_s, 3),
        "greedy_batched_s": round(greedy_s, 3),
        "greedy_speedup_x": round(greedy_scalar_s / greedy_s, 1),
        "greedy_verdict_mismatches": greedy_mismatches,
        "sharded": {"devices": mesh.size,
                    "seconds": round(sharded_s, 3),
                    "parity_ok": sharded_parity_ok},
        "distributed": {
            # single-process CI measures the streaming enumerator; a
            # pod-scale run (jax.distributed) self-describes here
            "processes": jax.process_count(),
            "chunk_rows": chunk_rows,
            "chunks_evaluated": chunk_tel["evaluated"],
            "rows": chunk_tel["rows"],
            "padded_rows": chunk_tel["padded_rows"],
            "seconds": round(streamed_s, 3),
            "parity_ok": streamed_parity_ok,
        },
        "pallas": {
            "mode": status["mode"],
            # only a real fallback (mode == "unavailable") is a fallback;
            # interpret mode still runs the kernel on every query
            "fallback_reason": (status["reason"]
                                if status["mode"] == "unavailable"
                                else None),
            "plan_s": round(pallas_s, 3),
            "verdict_mismatches": pallas_mismatches,
            "large_batch": large_batch_block,
            "sanity_ok": pallas_sanity_ok,
        },
        "precision": precision_block,
        "sanity_ok": sanity_ok,
        "cache": cache_after_cached,
        "provenance": _provenance(),
    }
    rows = [{"backend": "scalar", "seconds": round(scalar_s, 4)},
            {"backend": "vectorized_cold_jit", "seconds": round(cold_s, 4)},
            {"backend": "vectorized", "seconds": round(batched_s, 4)},
            {"backend": "vectorized_cached", "seconds": round(cached_s, 4)},
            {"backend": "scalar_greedy",
             "seconds": round(greedy_scalar_s, 4)},
            {"backend": "vectorized_greedy", "seconds": round(greedy_s, 4)},
            {"backend": f"vectorized_sharded_{mesh.size}dev",
             "seconds": round(sharded_s, 4)},
            {"backend": f"streamed_{chunk_tel['evaluated']}"
                        f"chunks_{chunk_rows}rows",
             "seconds": round(streamed_s, 4)},
            {"backend": f"pallas_{status['mode']}",
             "seconds": round(pallas_s, 4)}] + large_rows
    if write_json:
        out = os.environ.get("BENCH_PLANNER_OUT", "BENCH_planner.json")
        # preserve the campaign bench's block if already recorded (the
        # two benches share the file; each owns its keys)
        if os.path.exists(out):
            try:
                with open(out) as f:
                    prev = json.load(f)
                if "campaign" in prev:
                    derived["campaign"] = prev["campaign"]
            except (json.JSONDecodeError, OSError):
                pass
        if (derived["verdict_mismatches"]
                or derived["greedy_verdict_mismatches"]
                or pallas_mismatches
                or not pallas_sanity_ok
                or not precision_parity_ok
                or not sharded_parity_ok or not streamed_parity_ok
                or not sanity_ok):
            # quarantine: callers like benchmarks/run.py don't see the
            # __main__ gates below, and a bad run must not silently
            # replace the trusted trajectory entry
            out += ".failed"
        with open(out, "w") as f:
            json.dump(derived, f, indent=1)
    return rows, derived


if __name__ == "__main__":
    _, derived = planner_sweep_speed()
    print(json.dumps(derived, indent=1))
    # CI runs this module directly: a parity regression or a mismeasured
    # run must turn the job red, not just ship a json artifact recording
    # the breakage as the official trajectory entry
    bad = derived["verdict_mismatches"] + derived["greedy_verdict_mismatches"]
    if bad:
        sys.exit(f"verdict parity regression: batched != scalar on "
                 f"{bad} GEMMs (exact + greedy)")
    if derived["pallas"]["verdict_mismatches"]:
        sys.exit(f"pallas parity regression: pallas != vectorized on "
                 f"{derived['pallas']['verdict_mismatches']} GEMMs")
    prec_bad = {tok: blk["pallas_verdict_mismatches"]
                for tok, blk in derived["precision"].items()
                if blk["pallas_verdict_mismatches"]}
    if prec_bad:
        sys.exit(f"precision-axis parity regression: pallas != vectorized "
                 f"at {prec_bad}")
    if not derived["pallas"]["sanity_ok"]:
        sys.exit("pallas large-batch sanity violated: the compiled fused "
                 "kernel is slower than XLA fusion (see WARNING above)")
    if not derived["sharded"]["parity_ok"]:
        sys.exit("sharded parity regression: row-sharded metrics differ "
                 "from the single-device engine")
    if not derived["distributed"]["parity_ok"]:
        sys.exit("streamed parity regression: chunked evaluation differs "
                 "from the whole-batch engine")
    if not derived["sanity_ok"]:
        sys.exit("timing sanity violated (see WARNING above): rerun on a "
                 "quiet machine before trusting this artifact")
