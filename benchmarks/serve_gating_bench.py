"""Planner-gated serving benchmark: gated vs ungated INT8 decode.

For each benchmarked arch (reduced CPU smoke configs — the mechanism is
what's measured, not TPU throughput) it builds two quantized
ServeSessions over identical weights:

  * gated   — the What/When/Where verdicts close the jitted decode step,
              so CiM-gated projection labels lower to the weight-
              stationary INT8 Pallas kernel;
  * ungated — same INT8 weights, every label forced onto the standard
              XLA path (KernelPlanTable.ungated()).

and records decode tokens/s for both, the % of projections the gated
program routed to the CiM path, and a logits-parity check (routing must
not change the math beyond kernel numerics).  Three gates protect the
trajectory entry (ROADMAP "make the gated path win"):

  * **parity**   — gated and ungated logits agree within PARITY_ATOL;
  * **gated-not-slower** — on every arch where the planner actually
    routes projections to CiM (cim_routed_pct > 0), the gated program
    must not decode slower than the ungated one (beyond the
    GATED_NOT_SLOWER_RTOL timing-noise band);
  * **trend**    — tokens/s vs the committed BENCH_serve.json baseline
    must not drop beyond the SERVE_TREND_RTOL band (benchmarks.trend);
    deltas are reported in the GitHub job summary when CI provides one.

Like sweep_bench, a run failing any gate is quarantined to
BENCH_serve.json.failed instead of replacing the trusted trajectory
entry, and running the module directly (as CI does) then exits nonzero.

Each arch is measured in its **own subprocess** (``--arch ... --emit-row``
child mode): measuring several archs in one process depresses the
later-measured ones by 10-45% — XLA:CPU allocator/cache state left by
the earlier sessions, not anything about the arch — which is enough to
flip the trend gate on pure measurement artifact.  Fresh-process
isolation makes every arch's number order-independent.  Set
SERVE_GATING_INPROC=1 to force the old single-process sweep (or as the
automatic fallback when spawning fails).

Run directly:  PYTHONPATH=src python -m benchmarks.serve_gating_bench
(--new-tokens/--repeats/--warmup tune the shared timing helper,
repro.launch.serve.steady_decode_tokens_per_s).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, RunConfig, reduced
from repro.launch.serve import steady_decode_tokens_per_s
from repro.models import init
from repro.serving import ServeSession, cim_fraction

from .sweep_bench import _provenance
from .trend import (committed_baseline, emit_job_summary, render_markdown,
                    trend_report)

# arch -> decode batch.  mamba2 at batch 8 is the mixed-verdict case
# (ssm-BCdt gates on, the rest stay standard); the attention archs'
# smoke-size decode GEMVs are all "don't CiM" — the paper's M=1
# pathology — so their gated program must equal the ungated one.
BENCH_ARCHS = (("mamba2-780m", 8), ("mistral-nemo-12b", 8),
               ("qwen2-moe-a2.7b", 8))
PROMPT_LEN = 6
NEW_TOKENS = 16
# gated vs ungated differ only by kernel (Pallas f32-accum vs XLA bf16
# dequant matmul); logits are O(1) scale in the smoke models
PARITY_ATOL = 0.05
# gated-not-slower noise band: when the true gated/ungated difference is
# ~0 (the paper's answer on the attention archs IS "don't CiM at decode",
# so the programs are near-identical), CPU smoke timing jitters +-1-2%
# and a strict >= gate coin-flips.  2% lets noise through but still
# catches any real slowdown (the donation mis-default cost 20%).
GATED_NOT_SLOWER_RTOL = 0.02


def _measure_arch(arch: str, batch: int, new_tokens: int,
                  repeats: int, warmup: int) -> dict:
    """One arch's gated-vs-ungated measurement (runs in-process; the
    parent normally invokes it in a fresh subprocess via --emit-row)."""
    rc = RunConfig(attn_impl="naive", remat=False)
    cfg = reduced(ARCHS[arch])
    params = init(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (batch, PROMPT_LEN), 0, cfg.vocab)
    max_len = PROMPT_LEN + new_tokens + 2
    gated = ServeSession(cfg, rc, params, max_len=max_len,
                         batch=batch, quantize=True)
    ungated = ServeSession(cfg, rc, params, max_len=max_len,
                           batch=batch, quantize=True, gated=False)

    # parity first (prefill on fresh caches), then throughput
    lg = gated.prefill(prompt).astype(jnp.float32)
    lu = ungated.prefill(prompt).astype(jnp.float32)
    max_diff = float(jnp.max(jnp.abs(lg - lu)))
    parity_ok = max_diff <= PARITY_ATOL

    # interleaved sampling (launch.serve helper): contention hits
    # gated and ungated symmetrically, jit compile excluded
    tps_g, tps_u = steady_decode_tokens_per_s(
        (gated, ungated), prompt, new_tokens,
        repeats=repeats, warmup=warmup)
    routes = gated.route_report()
    row = {"arch": cfg.name, "batch": batch,
           "tokens_per_s_gated": round(tps_g, 1),
           "tokens_per_s_ungated": round(tps_u, 1),
           "cim_routed_pct": round(100.0 * cim_fraction(routes), 1),
           "parity_max_abs_diff": round(max_diff, 5),
           "parity_ok": parity_ok}
    return {
        **row, "routes": {lab: r["route"] for lab, r in routes.items()},
        # None when the private jit-cache probe is unavailable (the
        # retrace gate below then skips rather than false-failing)
        "decode_executables": gated.decode_executables}


_ROW_MARK = "GATING_ROW_JSON:"


def _measure_arch_isolated(arch: str, batch: int, new_tokens: int,
                           repeats: int, warmup: int) -> dict:
    """Measure one arch in a fresh python process so its timing never
    sees another arch's allocator/cache residue (10-45% depression when
    measured after other archs in-process).  Falls back to in-process on
    spawn failure or SERVE_GATING_INPROC=1."""
    if os.environ.get("SERVE_GATING_INPROC"):
        return _measure_arch(arch, batch, new_tokens, repeats, warmup)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"),
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.serve_gating_bench",
           "--arch", arch, "--batch", str(batch), "--emit-row",
           "--new-tokens", str(new_tokens), "--repeats", str(repeats),
           "--warmup", str(warmup)]
    try:
        proc = subprocess.run(cmd, cwd=root, env=env, text=True,
                              capture_output=True, timeout=1800)
        for line in proc.stdout.splitlines():
            if line.startswith(_ROW_MARK):
                return json.loads(line[len(_ROW_MARK):])
        raise RuntimeError(proc.stderr[-500:] or "no row emitted")
    except Exception as e:                        # noqa: BLE001
        print(f"serve_gating_bench: subprocess measurement of {arch} "
              f"failed ({e}); measuring in-process", file=sys.stderr)
        return _measure_arch(arch, batch, new_tokens, repeats, warmup)


def serve_gating_speed(write_json: bool = True, new_tokens: int = NEW_TOKENS,
                       repeats: int = 3, warmup: int = 0):
    rows, per_arch = [], {}
    all_parity_ok = True
    for arch, batch in BENCH_ARCHS:
        entry = _measure_arch_isolated(arch, batch, new_tokens,
                                       repeats, warmup)
        all_parity_ok &= entry["parity_ok"]
        rows.append({k: entry[k] for k in
                     ("arch", "batch", "tokens_per_s_gated",
                      "tokens_per_s_ungated", "cim_routed_pct",
                      "parity_max_abs_diff", "parity_ok")})
        per_arch[entry["arch"]] = entry

    # gated-not-slower: wherever the planner routed anything to CiM the
    # gated program must win (or tie, within the timing-noise band) —
    # the whole point of the gate
    gated_not_slower = all(
        r["tokens_per_s_gated"] >=
        r["tokens_per_s_ungated"] * (1.0 - GATED_NOT_SLOWER_RTOL)
        for r in rows if r["cim_routed_pct"] > 0)

    # perf-trend lane: deltas vs the committed baseline's archs block
    base_archs = (committed_baseline() or {}).get("archs", {})
    pairs = []
    for r in rows:
        prev = base_archs.get(r["arch"], {})
        for key in ("tokens_per_s_gated", "tokens_per_s_ungated"):
            pairs.append((f"{r['arch']} {key}", prev.get(key), r[key]))
    trend = trend_report(pairs)
    emit_job_summary(render_markdown("serve_gating_bench trend", trend))

    derived = {
        "archs": per_arch,
        "parity_ok": all_parity_ok,
        "parity_atol": PARITY_ATOL,
        "new_tokens": new_tokens,
        "gates": {
            "parity_ok": all_parity_ok,
            "gated_not_slower_ok": gated_not_slower,
            "trend_ok": trend["ok"],
        },
        "trend": trend,
        "provenance": _provenance(),
    }
    all_ok = all(derived["gates"].values())
    if write_json:
        out = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
        # preserve the traffic and adaptive benches' blocks if already
        # recorded (the three benches share the file; each owns its keys)
        if os.path.exists(out):
            try:
                with open(out) as f:
                    prev = json.load(f)
                for key in ("traffic", "adaptive"):
                    if key in prev:
                        derived[key] = prev[key]
            except (json.JSONDecodeError, OSError):
                pass
        if not all_ok:
            # quarantine: a gate-violating run must not replace the
            # trusted trajectory entry
            out += ".failed"
        with open(out, "w") as f:
            json.dump(derived, f, indent=1)
    return rows, derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Planner-gated serving benchmark (gated vs ungated "
                    "INT8 decode).",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--new-tokens", type=int, default=NEW_TOKENS,
                    help="decode steps per timed sample")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed samples per session (best is kept)")
    ap.add_argument("--warmup", type=int, default=0,
                    help="untimed decode steps per session after prefill")
    ap.add_argument("--arch", default=None,
                    help="child mode: measure just this arch")
    ap.add_argument("--batch", type=int, default=8,
                    help="child mode: decode batch for --arch")
    ap.add_argument("--emit-row", action="store_true",
                    help="child mode: print the arch row as JSON and exit")
    cli = ap.parse_args()
    if cli.emit_row:
        # fresh-process measurement child spawned by serve_gating_speed
        entry = _measure_arch(cli.arch, cli.batch, cli.new_tokens,
                              cli.repeats, cli.warmup)
        print(_ROW_MARK + json.dumps(entry))
        sys.exit(0)
    _, derived = serve_gating_speed(new_tokens=cli.new_tokens,
                                    repeats=cli.repeats, warmup=cli.warmup)
    print(json.dumps(derived, indent=1))
    if not derived["parity_ok"]:
        sys.exit("gating parity regression: gated and ungated INT8 decode "
                 "disagree beyond kernel-numerics tolerance")
    if not derived["gates"]["gated_not_slower_ok"]:
        sys.exit("gating speed regression: a CiM-routed arch decoded "
                 "slower gated than ungated")
    if not derived["gates"]["trend_ok"]:
        sys.exit("perf-trend regression: tokens/s dropped beyond the "
                 "SERVE_TREND_RTOL band vs the committed baseline")
    bad_retrace = [a for a, d in derived["archs"].items()
                   if d["decode_executables"] not in (1, None)]
    if bad_retrace:
        sys.exit(f"retrace regression: {bad_retrace} compiled more than "
                 "one decode executable")
