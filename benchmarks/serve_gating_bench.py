"""Planner-gated serving benchmark: gated vs ungated INT8 decode.

For each benchmarked arch (reduced CPU smoke configs — the mechanism is
what's measured, not TPU throughput) it builds two quantized
ServeSessions over identical weights:

  * gated   — the What/When/Where verdicts close the jitted decode step,
              so CiM-gated projection labels lower to the weight-
              stationary INT8 Pallas kernel;
  * ungated — same INT8 weights, every label forced onto the standard
              XLA path (KernelPlanTable.ungated()).

and records decode tokens/s for both, the % of projections the gated
program routed to the CiM path, and a logits-parity check (routing must
not change the math beyond kernel numerics).  Like sweep_bench, a run
failing the parity gate is quarantined to BENCH_serve.json.failed instead
of replacing the trusted trajectory entry, and running the module
directly (as CI does) then exits nonzero.

Run directly:  PYTHONPATH=src python -m benchmarks.serve_gating_bench
(--new-tokens/--repeats/--warmup tune the shared timing helper,
repro.launch.serve.steady_decode_tokens_per_s).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, RunConfig, reduced
from repro.launch.serve import steady_decode_tokens_per_s
from repro.models import init
from repro.serving import ServeSession, cim_fraction

from .sweep_bench import _provenance

# arch -> decode batch.  mamba2 at batch 8 is the mixed-verdict case
# (ssm-BCdt gates on, the rest stay standard); the attention archs'
# smoke-size decode GEMVs are all "don't CiM" — the paper's M=1
# pathology — so their gated program must equal the ungated one.
BENCH_ARCHS = (("mamba2-780m", 8), ("mistral-nemo-12b", 8),
               ("qwen2-moe-a2.7b", 8))
PROMPT_LEN = 6
NEW_TOKENS = 16
# gated vs ungated differ only by kernel (Pallas f32-accum vs XLA bf16
# dequant matmul); logits are O(1) scale in the smoke models
PARITY_ATOL = 0.05


def serve_gating_speed(write_json: bool = True, new_tokens: int = NEW_TOKENS,
                       repeats: int = 3, warmup: int = 0):
    rc = RunConfig(attn_impl="naive", remat=False)
    rows, per_arch = [], {}
    all_parity_ok = True
    for arch, batch in BENCH_ARCHS:
        cfg = reduced(ARCHS[arch])
        params = init(jax.random.PRNGKey(0), cfg)
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (batch, PROMPT_LEN), 0, cfg.vocab)
        max_len = PROMPT_LEN + new_tokens + 2
        gated = ServeSession(cfg, rc, params, max_len=max_len,
                             batch=batch, quantize=True)
        ungated = ServeSession(cfg, rc, params, max_len=max_len,
                               batch=batch, quantize=True, gated=False)

        # parity first (prefill on fresh caches), then throughput
        lg = gated.prefill(prompt).astype(jnp.float32)
        lu = ungated.prefill(prompt).astype(jnp.float32)
        max_diff = float(jnp.max(jnp.abs(lg - lu)))
        parity_ok = max_diff <= PARITY_ATOL
        all_parity_ok &= parity_ok

        # interleaved sampling (launch.serve helper): contention hits
        # gated and ungated symmetrically, jit compile excluded
        tps_g, tps_u = steady_decode_tokens_per_s(
            (gated, ungated), prompt, new_tokens,
            repeats=repeats, warmup=warmup)
        routes = gated.route_report()
        row = {"arch": cfg.name, "batch": batch,
               "tokens_per_s_gated": round(tps_g, 1),
               "tokens_per_s_ungated": round(tps_u, 1),
               "cim_routed_pct": round(100.0 * cim_fraction(routes), 1),
               "parity_max_abs_diff": round(max_diff, 5),
               "parity_ok": parity_ok}
        rows.append(row)
        per_arch[cfg.name] = {
            **row, "routes": {lab: r["route"] for lab, r in routes.items()},
            # None when the private jit-cache probe is unavailable (the
            # retrace gate below then skips rather than false-failing)
            "decode_executables": gated.decode_executables}

    derived = {
        "archs": per_arch,
        "parity_ok": all_parity_ok,
        "parity_atol": PARITY_ATOL,
        "new_tokens": new_tokens,
        "provenance": _provenance(),
    }
    if write_json:
        out = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
        # preserve the traffic and adaptive benches' blocks if already
        # recorded (the three benches share the file; each owns its keys)
        if os.path.exists(out):
            try:
                with open(out) as f:
                    prev = json.load(f)
                for key in ("traffic", "adaptive"):
                    if key in prev:
                        derived[key] = prev[key]
            except (json.JSONDecodeError, OSError):
                pass
        if not all_parity_ok:
            # quarantine: a routing-changes-the-math run must not replace
            # the trusted trajectory entry
            out += ".failed"
        with open(out, "w") as f:
            json.dump(derived, f, indent=1)
    return rows, derived


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Planner-gated serving benchmark (gated vs ungated "
                    "INT8 decode).",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--new-tokens", type=int, default=NEW_TOKENS,
                    help="decode steps per timed sample")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed samples per session (best is kept)")
    ap.add_argument("--warmup", type=int, default=0,
                    help="untimed decode steps per session after prefill")
    cli = ap.parse_args()
    _, derived = serve_gating_speed(new_tokens=cli.new_tokens,
                                    repeats=cli.repeats, warmup=cli.warmup)
    print(json.dumps(derived, indent=1))
    if not derived["parity_ok"]:
        sys.exit("gating parity regression: gated and ungated INT8 decode "
                 "disagree beyond kernel-numerics tolerance")
    bad_retrace = [a for a, d in derived["archs"].items()
                   if d["decode_executables"] not in (1, None)]
    if bad_retrace:
        sys.exit(f"retrace regression: {bad_retrace} compiled more than "
                 "one decode executable")
