"""Benchmark harness: one module function per paper table/figure."""
