"""Adaptive-planning serving benchmark: hot-swapped vs frozen decode plans.

Drives the continuous-batching engine twice over identical seeded
traffic (reduced CPU smoke configs — the swap mechanism is what's
measured, not TPU throughput):

  * **frozen** — the PR-6 path: one `KernelPlanTable` fixed at core
    build time, one compiled executable;
  * **adaptive (no flip)** — the shape-bucketed `PlanService`
    (repro.core.plan_service) consulted every step over a single-bucket
    lattice matching the core's planning shape, so every lookup returns
    the frozen plan: the engine must stay token-EXACT vs the frozen run
    with zero plan swaps (the adaptive machinery may not perturb
    serving when verdicts agree);
  * **adaptive (forced flip)** — an injected `plan_fn` toggles one
    label's verdict on the bucket's first background refresh: the
    engine must hot-swap (plan_swaps >= 1, verdict_flips >= 1) onto a
    second compiled variant without retracing the first
    (`decode_executables == plan_variants == 2` — one program per
    distinct plan table) and still complete every request.

Timing rows record adaptive vs frozen engine tokens/s (the service's
per-step lookup overhead) and the swap latency stats; gates are purely
deterministic (token equality, swap/executable counts, completion).
Like the gating and traffic benches, a gate-violating run quarantines
to BENCH_serve.json.failed instead of replacing the trusted trajectory
entry, and running the module directly (as CI does) then exits nonzero.
The `adaptive` block *merges* into BENCH_serve.json next to the gating
and `traffic` blocks — the three benches share the file; each owns its
keys.

Run directly:  PYTHONPATH=src python -m benchmarks.serve_adaptive_bench
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax

from repro.configs import ARCHS, RunConfig, reduced
from repro.core.plan_service import BucketLattice, PlanService
from repro.models import init
from repro.serving import (ContinuousBatchingEngine, DecodeCore,
                           synthetic_requests)

from .sweep_bench import _provenance

ARCH = "mamba2-780m"       # mixed-verdict gated smoke model
N_SLOTS = 3
BLOCK_SIZE = 4
N_REQUESTS = 8
PROMPT_RANGE = (4, 10)
NEW_RANGE = (6, 14)
SEED = 0
REFRESH_EVERY = 4          # forced-flip scenario: re-plan after 4 hits


def _max_len() -> int:
    return PROMPT_RANGE[1] + NEW_RANGE[1] + 2


def _requests(cfg, n: int):
    return synthetic_requests(cfg, n, seed=SEED, prompt_len=PROMPT_RANGE,
                              new_tokens=NEW_RANGE)


def _engine(core, service=None):
    return ContinuousBatchingEngine(core, n_slots=N_SLOTS,
                                    max_len=_max_len(),
                                    block_size=BLOCK_SIZE, seed=SEED,
                                    plan_service=service)


def _tokens_by_rid(engine) -> dict:
    return {r.rid: [int(t) for t in r.tokens] for r in engine.completed}


def make_flipping_plan_fn(service_cfg, flip_after: int = 1):
    """A PlanService plan_fn that returns the real batched-sweep verdicts
    for the first `flip_after` builds of a shape, then toggles the
    lexicographically-first label's gate — the deterministic forced-flip
    harness (shared with tests/test_adaptive_planning.py)."""
    from repro.core.llm_workloads import gemms_of_model
    from repro.core.planner import plan_workload
    builds: dict = {}

    def plan_fn(shape):
        decisions = plan_workload(gemms_of_model(service_cfg, shape),
                                  backend="vectorized")
        n = builds.get(shape.name, 0)
        builds[shape.name] = n + 1
        if n < flip_after:
            return decisions
        flip_label = min(d.gemm.label for d in decisions)
        return [dataclasses.replace(d, use_cim=not d.use_cim)
                if d.gemm.label == flip_label else d for d in decisions]

    return plan_fn


def serve_adaptive(write_json: bool = True,
                   n_requests: int = N_REQUESTS) -> dict:
    cfg = reduced(ARCHS[ARCH])
    rc = RunConfig(attn_impl="naive", remat=False)
    params = init(jax.random.PRNGKey(0), cfg)
    max_len = _max_len()
    single_bucket = BucketLattice((N_SLOTS,), (max_len,))

    def fresh_core():
        return DecodeCore(cfg, rc, params, quantize=True,
                          plan_batch=N_SLOTS, plan_max_len=max_len)

    # --- frozen reference (warmed: jit compile must not skew tokens/s) --
    frozen_core = fresh_core()
    _engine(frozen_core).run(_requests(cfg, 2), None)
    frozen_eng = _engine(frozen_core)
    frozen_t = frozen_eng.run(_requests(cfg, n_requests), None)
    frozen_tokens = _tokens_by_rid(frozen_eng)

    # --- adaptive, no flip: single bucket == the frozen planning shape --
    adaptive_core = fresh_core()
    _engine(adaptive_core).run(_requests(cfg, 2), None)
    service = PlanService(cfg, single_bucket, background=False)
    adaptive_eng = _engine(adaptive_core, service)
    adaptive_t = adaptive_eng.run(_requests(cfg, n_requests), None)
    no_flip_ad = adaptive_t["adaptive"]
    no_flip = {
        "engine_tokens_per_s":
            adaptive_t["aggregate"]["engine_tokens_per_s"],
        "completed": adaptive_t["aggregate"]["completed"],
        "tokens_equal": _tokens_by_rid(adaptive_eng) == frozen_tokens,
        "plan_swaps": no_flip_ad["plan_swaps"],
        "verdict_flips": no_flip_ad["service"]["verdict_flips"],
        "bucket_hit_rate": no_flip_ad["service"]["hit_rate"],
        "decode_executables": adaptive_core.batch_decode_executables,
        "swap_latency_s": no_flip_ad["swap_latency_s"],
        "service": no_flip_ad["service"],
    }

    # --- adaptive, forced flip: the bucket's first refresh toggles one
    # verdict; the engine must swap onto a second compiled variant -------
    flip_core = fresh_core()
    flip_service = PlanService(cfg, single_bucket,
                               refresh_every=REFRESH_EVERY,
                               background=False,
                               plan_fn=make_flipping_plan_fn(cfg))
    flip_eng = _engine(flip_core, flip_service)
    flip_t = flip_eng.run(_requests(cfg, n_requests), None)
    flip_ad = flip_t["adaptive"]
    forced_flip = {
        "engine_tokens_per_s": flip_t["aggregate"]["engine_tokens_per_s"],
        "completed": flip_t["aggregate"]["completed"],
        "plan_swaps": flip_ad["plan_swaps"],
        "verdict_flips": flip_ad["service"]["verdict_flips"],
        "plan_variants": flip_core.plan_variants,
        "decode_executables": flip_core.batch_decode_executables,
        "swap_latency_s": flip_ad["swap_latency_s"],
        "service": flip_ad["service"],
    }

    execs = forced_flip["decode_executables"]
    adaptive = {
        "arch": cfg.name,
        "n_slots": N_SLOTS,
        "block_size": BLOCK_SIZE,
        "requests": n_requests,
        "seed": SEED,
        "refresh_every": REFRESH_EVERY,
        "frozen_tokens_per_s": frozen_t["aggregate"]["engine_tokens_per_s"],
        "no_flip": no_flip,
        "forced_flip": forced_flip,
        "gates": {
            # verdict agreement => the adaptive path may not perturb
            # serving at all: identical tokens, zero swaps
            "no_flip_token_parity": bool(no_flip["tokens_equal"]),
            "no_flip_zero_swaps": no_flip["plan_swaps"] == 0,
            # a flip must actually swap...
            "flip_swapped": (forced_flip["plan_swaps"] >= 1
                             and forced_flip["verdict_flips"] >= 1),
            # ...onto exactly one compiled program per distinct plan,
            # never retracing the active variant
            "flip_no_retrace": (execs is None
                                or execs == forced_flip["plan_variants"]
                                == 2),
            "all_completed": (frozen_t["aggregate"]["completed"]
                              == no_flip["completed"]
                              == forced_flip["completed"]
                              == n_requests),
        },
        "provenance": _provenance(),
    }
    ok = all(adaptive["gates"].values())
    if write_json:
        out = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
        merged = {}
        if os.path.exists(out):
            try:
                with open(out) as f:
                    merged = json.load(f)
            except (json.JSONDecodeError, OSError):
                merged = {}
        merged["adaptive"] = adaptive
        if not ok:
            # quarantine: a gate-violating run must not replace the
            # trusted trajectory entry
            out += ".failed"
        with open(out, "w") as f:
            json.dump(merged, f, indent=1)
    return adaptive


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Adaptive-planning serving benchmark (hot-swapped vs "
                    "frozen decode plans).",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--requests", type=int, default=N_REQUESTS,
                    help="requests per scenario")
    cli = ap.parse_args()
    adaptive = serve_adaptive(n_requests=cli.requests)
    print(json.dumps(adaptive, indent=1))
    gates = adaptive["gates"]
    if not gates["no_flip_token_parity"]:
        sys.exit("adaptive parity regression: agreeing verdicts changed "
                 "the served tokens vs the frozen-plan engine")
    if not gates["no_flip_zero_swaps"]:
        sys.exit("adaptive stability regression: the engine swapped "
                 "plans although no verdict flipped")
    if not gates["flip_swapped"]:
        sys.exit("adaptive swap regression: a forced verdict flip did "
                 "not hot-swap the decode plan")
    if not gates["flip_no_retrace"]:
        sys.exit("retrace regression: plan hot-swap compiled more than "
                 "one program per distinct plan table")
    if not gates["all_completed"]:
        sys.exit("adaptive completeness regression: requests were lost")
