"""Beyond-paper benchmark: what/when/where decisions over the 10 assigned
LM architectures' GEMMs (the paper's methodology applied to the framework's
own workloads).

For each (arch x shape) the planner evaluates every GEMM and reports the
CiM-offload fraction and projected energy gain — train/prefill shapes land
in the paper's "CiM wins" regime, decode shapes in the "don't CiM" regime
(Table V), which is exactly what gates the INT8 weight-stationary kernel
path in repro.quant.planned_linear.

All cells route through the batched sweep engine (plan_workload's default
vectorized backend): one fused device evaluation per cell instead of a
scalar cost-model call per (GEMM x config), with results LRU-cached
across cells.
"""
from __future__ import annotations

from repro.configs import ARCHS, SHAPES
from repro.core.llm_workloads import gemms_of_model
from repro.core.planner import plan_workload, summarize
from repro.core import DIGITAL_6T, ANALOG_8T, CiMSystemConfig, configb_count


def _dedupe(gemms):
    seen = {}
    for g in gemms:
        key = (g.M, g.N, g.K)
        if key in seen:
            seen[key] = seen[key].scaled(count=seen[key].count + g.count)
        else:
            seen[key] = g
    return list(seen.values())


def planner_decisions(max_gemms_per_cell: int = 12):
    cfgs = {
        "Digital-6T@RF": CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF"),
        "Digital-6T@SMEM-B": CiMSystemConfig(
            prim=DIGITAL_6T, cim_level="SMEM",
            n_prims=configb_count(DIGITAL_6T)),
        "Analog-8T@RF": CiMSystemConfig(prim=ANALOG_8T, cim_level="RF"),
    }
    rows = []
    for arch, mc in ARCHS.items():
        for sname in ("train_4k", "decode_32k"):
            shape = SHAPES[sname]
            gemms = _dedupe(gemms_of_model(mc, shape))
            gemms = sorted(gemms, key=lambda g: -g.ops * g.count
                           )[:max_gemms_per_cell]
            decisions = plan_workload(gemms, cfgs, backend="vectorized")
            summary = summarize(decisions)
            rows.append({
                "arch": arch, "shape": sname,
                "n_gemms": summary["n_gemms"],
                "cim_fraction": summary["cim_fraction"],
                "energy_gain_x": summary["energy_gain_x"],
            })
    train_frac = [r["cim_fraction"] for r in rows
                  if r["shape"] == "train_4k"]
    dec_frac = [r["cim_fraction"] for r in rows
                if r["shape"] == "decode_32k"]
    return rows, {
        "mean_cim_fraction_train": sum(train_frac) / len(train_frac),
        "mean_cim_fraction_decode": sum(dec_frac) / len(dec_frac),
    }
