"""Beyond-paper benchmark: what/when/where decisions over the 10 assigned
LM architectures' GEMMs (the paper's methodology applied to the framework's
own workloads).

For each (arch x shape) the planner evaluates every GEMM and reports the
CiM-offload fraction and projected energy gain — train/prefill shapes land
in the paper's "CiM wins" regime, decode shapes in the "don't CiM" regime
(Table V), which is exactly what gates the INT8 weight-stationary kernel
path in repro.quant.planned_linear.
"""
from __future__ import annotations

from repro.configs import ARCHS, SHAPES
from repro.core.llm_workloads import gemms_of_model
from repro.core.planner import decide, standard_configs
from repro.core import DIGITAL_6T, ANALOG_8T, CiMSystemConfig, configb_count


def _dedupe(gemms):
    seen = {}
    for g in gemms:
        key = (g.M, g.N, g.K)
        if key in seen:
            seen[key] = seen[key].scaled(count=seen[key].count + g.count)
        else:
            seen[key] = g
    return list(seen.values())


def planner_decisions(max_gemms_per_cell: int = 12):
    cfgs = {
        "Digital-6T@RF": CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF"),
        "Digital-6T@SMEM-B": CiMSystemConfig(
            prim=DIGITAL_6T, cim_level="SMEM",
            n_prims=configb_count(DIGITAL_6T)),
        "Analog-8T@RF": CiMSystemConfig(prim=ANALOG_8T, cim_level="RF"),
    }
    rows = []
    for arch, mc in ARCHS.items():
        for sname in ("train_4k", "decode_32k"):
            shape = SHAPES[sname]
            gemms = _dedupe(gemms_of_model(mc, shape))
            gemms = sorted(gemms, key=lambda g: -g.ops * g.count
                           )[:max_gemms_per_cell]
            n_cim = 0
            e_base = e_best = 0.0
            for g in gemms:
                d = decide(g, cfgs)
                n_cim += d.use_cim
                e_base += d.baseline.energy_pj * g.count
                e_best += min(d.baseline.energy_pj,
                              min(m.energy_pj for m in
                                  d.options.values())) * g.count
            rows.append({
                "arch": arch, "shape": sname, "n_gemms": len(gemms),
                "cim_fraction": n_cim / max(1, len(gemms)),
                "energy_gain_x": e_base / max(e_best, 1e-9),
            })
    train_frac = [r["cim_fraction"] for r in rows
                  if r["shape"] == "train_4k"]
    dec_frac = [r["cim_fraction"] for r in rows
                if r["shape"] == "decode_32k"]
    return rows, {
        "mean_cim_fraction_train": sum(train_frac) / len(train_frac),
        "mean_cim_fraction_decode": sum(dec_frac) / len(dec_frac),
    }
