"""Perf-trend lane shared by the serving benchmarks.

Both serving benches (serve_gating_bench, serve_traffic_bench) compare
their freshly-measured tokens/s against the *committed*
BENCH_serve.json baseline — git HEAD's copy when the repo is available,
the on-disk file otherwise (a fresh CI checkout makes the two
identical) — and report per-metric deltas.  A drop beyond the tolerance
band (SERVE_TREND_RTOL, default 0.25: CPU smoke timings jitter run to
run, so the band catches collapse-scale regressions, not noise) is a
trend regression and quarantines the run exactly like a parity failure.

Deltas land in the bench JSON under each bench's "trend" key and, when
CI provides $GITHUB_STEP_SUMMARY, as a markdown table in the job
summary.  SERVE_TREND_BASELINE points the comparison at an explicit
baseline file (tests use it to avoid depending on git state).
"""
from __future__ import annotations

import json
import os
import subprocess

DEFAULT_RTOL = 0.25


def trend_rtol() -> float:
    return float(os.environ.get("SERVE_TREND_RTOL", DEFAULT_RTOL))


def committed_baseline(path: str = "BENCH_serve.json") -> dict | None:
    """The committed benchmark file to trend against.

    SERVE_TREND_BASELINE (explicit file) wins; otherwise git HEAD's copy
    of `path`; otherwise the on-disk file; None when nothing exists yet
    (first trajectory entry — every trend row then passes vacuously)."""
    override = os.environ.get("SERVE_TREND_BASELINE")
    if override:
        try:
            with open(override) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None
    try:
        proc = subprocess.run(
            ["git", "show", f"HEAD:{os.path.basename(path)}"],
            capture_output=True, text=True, timeout=30,
            cwd=os.path.dirname(os.path.abspath(path)))
        if proc.returncode == 0:
            return json.loads(proc.stdout)
    except (OSError, subprocess.SubprocessError, json.JSONDecodeError):
        pass
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def trend_report(pairs, rtol: float | None = None) -> dict:
    """pairs: iterable of (metric_label, baseline_value|None, current).
    A row regresses when current < baseline * (1 - rtol); rows with no
    baseline (new metric / first entry) pass vacuously."""
    if rtol is None:
        rtol = trend_rtol()
    rows, ok = [], True
    for label, base, cur in pairs:
        if not base:
            rows.append({"metric": label, "baseline": base,
                         "current": round(cur, 1), "delta_pct": None,
                         "ok": True})
            continue
        row_ok = cur >= base * (1.0 - rtol)
        ok &= row_ok
        rows.append({"metric": label, "baseline": base,
                     "current": round(cur, 1),
                     "delta_pct": round(100.0 * (cur - base) / base, 1),
                     "ok": row_ok})
    return {"rtol": rtol, "rows": rows, "ok": ok}


def render_markdown(title: str, report: dict) -> str:
    lines = [f"### {title}", "",
             f"tolerance band: -{100.0 * report['rtol']:.0f}% "
             "(SERVE_TREND_RTOL)", "",
             "| metric | baseline | current | delta | ok |",
             "|---|---:|---:|---:|:--:|"]
    for r in report["rows"]:
        delta = ("n/a" if r["delta_pct"] is None
                 else f"{r['delta_pct']:+.1f}%")
        base = "n/a" if not r["baseline"] else f"{r['baseline']}"
        mark = "ok" if r["ok"] else "**REGRESSION**"
        lines.append(f"| {r['metric']} | {base} | {r['current']} "
                     f"| {delta} | {mark} |")
    return "\n".join(lines) + "\n"


def emit_job_summary(md: str) -> None:
    """Append to the GitHub Actions job summary when CI provides one."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a") as f:
            f.write(md + "\n")
