"""Continuous-batching traffic benchmark: throughput vs latency curves.

Drives the slot-scheduled, paged-KV request engine
(repro.serving.ContinuousBatchingEngine) with seeded synthetic ragged
requests arriving as an open-loop Poisson process at several rates, and
records one throughput-vs-latency row per rate (TTFT p50/p95, engine
tokens/s, queue depth, slot occupancy, evictions).  Reduced CPU smoke
configs — the scheduling mechanism is what's measured, not TPU
throughput; the curves' *shape* (TTFT rising with arrival rate while
engine tokens/s saturates) is the trajectory signal.

Three gate families protect the numbers:

  * **parity** — for each parity arch, every request served through the
    continuous engine must produce exactly the tokens the legacy
    fixed-batch `ServeSession(batch=1)` produces for it alone, and the
    first-token logits must match within kernel-numerics tolerance
    (PARITY_ATOL shared with serve_gating_bench).  mamba2-780m is the
    mixed-verdict gated case; mistral-nemo-12b exercises the paged KV
    path across block boundaries.
  * **no-retrace** — after all traffic at all rates,
    `decode_executables == 1`: every ragged pattern hit one compiled
    masked step.
  * **trend** — engine tokens/s per rate and the fixed-batch anchor vs
    the committed BENCH_serve.json baseline must not drop beyond the
    SERVE_TREND_RTOL band (benchmarks.trend); deltas land in the GitHub
    job summary when CI provides one.

Like the gating bench, a run violating any gate is quarantined to
BENCH_serve.json.failed instead of replacing the trusted trajectory
entry, and running the module directly (as CI does) then exits nonzero.
The traffic block *merges* into the existing BENCH_serve.json next to
the gating block — the two benches share the file; each owns its keys.

Run directly:  PYTHONPATH=src python -m benchmarks.serve_traffic_bench
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, reduced
from repro.launch.serve import steady_decode_tokens_per_s
from repro.models import init
from repro.serving import (ContinuousBatchingEngine, DecodeCore,
                           ServeSession, poisson_arrivals,
                           synthetic_requests)

from .serve_gating_bench import PARITY_ATOL
from .sweep_bench import _provenance
from .trend import (committed_baseline, emit_job_summary, render_markdown,
                    trend_report)

# open-loop arrival rates (req/s): under-, near-, and over-saturated
# relative to the smoke engine's service rate (~25ms per tiny request,
# 4 slots) — three points draw the throughput-vs-latency knee: at the
# top rate occupancy passes 0.8 and the admission queue backs up, so
# TTFT percentiles lift off the flat low-rate floor
RATES = (4.0, 32.0, 256.0)
N_REQUESTS = 10            # requests per rate
N_SLOTS = 4
BLOCK_SIZE = 4             # small so smoke prompts cross block edges
PROMPT_RANGE = (4, 10)
NEW_RANGE = (6, 14)
SEED = 0
TRAFFIC_ARCH = "mamba2-780m"      # mixed-verdict gated smoke model
PARITY_ARCHS = ("mamba2-780m", "mistral-nemo-12b")


def _max_len() -> int:
    return PROMPT_RANGE[1] + NEW_RANGE[1] + 2


def _parity_case(arch: str) -> dict:
    """Serve a small batch through the continuous engine and through the
    legacy per-request session; require token equality + first-logits
    agreement."""
    cfg = reduced(ARCHS[arch])
    rc = RunConfig(attn_impl="naive", remat=False)
    params = init(jax.random.PRNGKey(0), cfg)
    max_len = _max_len()
    core = DecodeCore(cfg, rc, params, quantize=True,
                      plan_batch=3, plan_max_len=max_len)
    engine = ContinuousBatchingEngine(core, n_slots=3, max_len=max_len,
                                      block_size=BLOCK_SIZE, seed=SEED,
                                      record_logits=True)
    reqs = synthetic_requests(cfg, 4, seed=SEED,
                              prompt_len=PROMPT_RANGE,
                              new_tokens=NEW_RANGE)
    engine.run(reqs, None)

    legacy = ServeSession(cfg, rc, params, max_len=max_len, batch=1,
                          quantize=True)
    tokens_equal, max_logit_diff = True, 0.0
    for r in sorted(engine.completed, key=lambda r: r.rid):
        prompt = np.asarray(r.prompt)[None]
        legacy.reset()
        ref_logits = legacy.prefill(prompt).astype(jnp.float32)
        legacy.reset()
        ref = legacy.generate(prompt, n_new=r.max_new_tokens)
        got = np.asarray(r.tokens).reshape(-1)
        want = np.asarray(jax.device_get(ref)).reshape(-1)
        tokens_equal &= bool(np.array_equal(got, want))
        d = float(jnp.max(jnp.abs(
            jnp.asarray(r.first_logits, jnp.float32)
            - ref_logits[0, -1])))
        max_logit_diff = max(max_logit_diff, d)
    all_done = len(engine.completed) == len(reqs)
    return {"arch": cfg.name,
            "requests": len(reqs),
            "all_completed": all_done,
            "tokens_equal": tokens_equal,
            "first_logits_max_abs_diff": round(max_logit_diff, 5),
            "parity_ok": bool(tokens_equal and all_done
                              and max_logit_diff <= PARITY_ATOL),
            "decode_executables": engine.decode_executables}


def serve_traffic(write_json: bool = True, rates=RATES,
                  n_requests: int = N_REQUESTS) -> dict:
    cfg = reduced(ARCHS[TRAFFIC_ARCH])
    rc = RunConfig(attn_impl="naive", remat=False)
    params = init(jax.random.PRNGKey(0), cfg)
    max_len = _max_len()
    # fixed-batch anchor FIRST, while the process is fresh: the legacy
    # lockstep session at batch=N_SLOTS on the same weights, timed by
    # the shared helper (warmed, best-of).  Measured after the engine
    # curves it inherits their allocator/cache drag and reads up to 35%
    # low — the same in-process interference the gating bench dodges
    # with per-arch subprocesses.
    ref_sess = ServeSession(cfg, rc, params, max_len=max_len,
                            batch=N_SLOTS, quantize=True)
    ref_prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (N_SLOTS, PROMPT_RANGE[1]), 0,
                                    cfg.vocab)
    (ref_tps,) = steady_decode_tokens_per_s([ref_sess], ref_prompt,
                                            NEW_RANGE[1], repeats=5,
                                            warmup=2)
    del ref_sess

    core = DecodeCore(cfg, rc, params, quantize=True,
                      plan_batch=N_SLOTS, plan_max_len=max_len)

    # warm the one executable (jit compile must not pollute the first
    # rate's TTFT) — a throwaway engine over the same core
    warm = ContinuousBatchingEngine(core, n_slots=N_SLOTS,
                                    max_len=max_len,
                                    block_size=BLOCK_SIZE, seed=SEED)
    warm.run(synthetic_requests(cfg, 2, seed=SEED,
                                prompt_len=PROMPT_RANGE,
                                new_tokens=NEW_RANGE), None)

    curves, all_completed = [], True
    executables = set()
    for rate in rates:
        engine = ContinuousBatchingEngine(core, n_slots=N_SLOTS,
                                          max_len=max_len,
                                          block_size=BLOCK_SIZE,
                                          seed=SEED)
        reqs = synthetic_requests(cfg, n_requests, seed=SEED,
                                  prompt_len=PROMPT_RANGE,
                                  new_tokens=NEW_RANGE)
        arrivals = poisson_arrivals(n_requests, rate, seed=SEED)
        t = engine.run(reqs, arrivals)
        agg = t["aggregate"]
        all_completed &= agg["completed"] == n_requests
        executables.add(agg["decode_executables"])
        curves.append({
            "arrival_rate_req_per_s": rate,
            "completed": agg["completed"],
            "ttft_p50_s": agg["ttft_p50_s"],
            "ttft_p95_s": agg["ttft_p95_s"],
            "ttft_mean_s": agg["ttft_mean_s"],
            "engine_tokens_per_s": agg["engine_tokens_per_s"],
            "request_tokens_per_s_mean": agg["request_tokens_per_s_mean"],
            "queue_depth_mean": agg["queue_depth_mean"],
            "queue_depth_max": agg["queue_depth_max"],
            "slot_occupancy_mean": agg["slot_occupancy_mean"],
            "evictions": agg["evictions"],
            "kv_blocks_peak_in_use": agg["kv_blocks"]["peak_in_use"],
            "kv_donation_ok": agg["kv_donation_ok"],
            "decode_step_breakdown": agg["decode_step_breakdown"],
        })

    parity = [_parity_case(a) for a in PARITY_ARCHS]
    retrace_ok = all(e in (1, None) for e in executables) and all(
        p["decode_executables"] in (1, None) for p in parity)

    # perf-trend lane: engine throughput per rate + the fixed-batch
    # anchor vs the committed baseline's traffic block
    base = (committed_baseline() or {}).get("traffic", {})
    base_curves = {c["arrival_rate_req_per_s"]: c
                   for c in base.get("curves", [])}
    pairs = [(f"rate {c['arrival_rate_req_per_s']} engine tokens/s",
              base_curves.get(c["arrival_rate_req_per_s"], {})
              .get("engine_tokens_per_s"),
              c["engine_tokens_per_s"]) for c in curves]
    pairs.append(("fixed-batch reference tokens/s",
                  base.get("fixed_batch_reference_tokens_per_s"), ref_tps))
    trend = trend_report(pairs)
    emit_job_summary(render_markdown("serve_traffic_bench trend", trend))

    traffic = {
        "arch": cfg.name,
        "n_slots": N_SLOTS,
        "block_size": BLOCK_SIZE,
        "requests_per_rate": n_requests,
        "seed": SEED,
        "curves": curves,
        "fixed_batch_reference_tokens_per_s": round(ref_tps, 1),
        "parity": parity,
        "parity_atol": PARITY_ATOL,
        "trend": trend,
        "gates": {
            "parity_ok": all(p["parity_ok"] for p in parity),
            "all_completed": all_completed,
            "decode_executables_ok": retrace_ok,
            "trend_ok": trend["ok"],
        },
        "provenance": _provenance(),
    }
    ok = all(traffic["gates"].values())
    if write_json:
        out = os.environ.get("BENCH_SERVE_OUT", "BENCH_serve.json")
        merged = {}
        if os.path.exists(out):
            try:
                with open(out) as f:
                    merged = json.load(f)
            except (json.JSONDecodeError, OSError):
                merged = {}
        merged["traffic"] = traffic
        if not ok:
            # quarantine: a gate-violating run must not replace the
            # trusted trajectory entry
            out += ".failed"
        with open(out, "w") as f:
            json.dump(merged, f, indent=1)
    return traffic


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="Continuous-batching open-loop traffic benchmark.",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--requests", type=int, default=N_REQUESTS,
                    help="requests per arrival rate")
    ap.add_argument("--rates", type=float, nargs="+", default=list(RATES),
                    help="open-loop Poisson arrival rates (req/s)")
    cli = ap.parse_args()
    traffic = serve_traffic(rates=tuple(cli.rates),
                            n_requests=cli.requests)
    print(json.dumps(traffic, indent=1))
    gates = traffic["gates"]
    if not gates["parity_ok"]:
        sys.exit("traffic parity regression: continuous-batching decode "
                 "disagrees with the legacy per-request session")
    if not gates["all_completed"]:
        sys.exit("traffic completeness regression: requests were lost")
    if not gates["decode_executables_ok"]:
        sys.exit("retrace regression: ragged traffic compiled more than "
                 "one masked decode executable")
    if not gates["trend_ok"]:
        sys.exit("perf-trend regression: engine tokens/s dropped beyond "
                 "the SERVE_TREND_RTOL band vs the committed baseline")
