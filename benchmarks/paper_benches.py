"""One benchmark per paper table/figure (deliverable d).

Each function returns (rows, derived) where rows is a list of dicts
(written as CSV by run.py) and derived is a {metric: value} summary used
for the EXPERIMENTS.md reproduction checks.

The big sweeps (fig9/10/11-12/13) evaluate through the batched sweep
engine (repro.core.sweep: sweep_evaluate / sweep_evaluate_baseline — one
fused device call per batch of uncached points, LRU-cached across
figures); fig7 deliberately stays on the scalar path because its derived
metric *is* the scalar mapper's runtime vs the heuristic search.
"""
from __future__ import annotations

import statistics
import time

from repro.core import (ANALOG_6T, ANALOG_8T, DIGITAL_6T, DIGITAL_8T, GEMM,
                        CiMSystemConfig, REAL_WORKLOADS, configb_count,
                        evaluate, random_search, square_sweep,
                        sweep_evaluate, sweep_evaluate_baseline,
                        synthetic_dataset)
from repro.core.gemm import geomean

PRIMS = {"Analog-6T": ANALOG_6T, "Analog-8T": ANALOG_8T,
         "Digital-6T": DIGITAL_6T, "Digital-8T": DIGITAL_8T}
D6_RF = CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF")


def fig2_gemm_landscape():
    """Fig. 2: ops vs algorithmic reuse for the real ML workloads."""
    rows = []
    for wl, gemms in REAL_WORKLOADS.items():
        for g in gemms:
            rows.append({"workload": wl, "M": g.M, "N": g.N, "K": g.K,
                         "ops": g.ops, "algorithmic_reuse":
                         round(g.algorithmic_reuse, 3),
                         "count": g.count})
    bert = [r for r in rows if r["workload"] == "BERT-Large"]
    return rows, {"n_gemms": len(rows),
                  "bert_max_reuse": max(r["algorithmic_reuse"]
                                        for r in bert)}


def fig7_table2_mapping_vs_heuristic(n_shapes: int = 24, seed: int = 0):
    """Fig. 7 + Table II: priority mapper vs random heuristic search."""
    shapes = synthetic_dataset(n_shapes, seed=seed) \
        + REAL_WORKLOADS["BERT-Large"] + REAL_WORKLOADS["DLRM"]
    rows = []
    t_ours = t_heur = 0.0
    for g in shapes:
        t0 = time.perf_counter()
        ours = evaluate(g, D6_RF)
        t_ours += time.perf_counter() - t0
        t0 = time.perf_counter()
        found = random_search(g, D6_RF, seed=seed, max_valid=150,
                              max_consecutive_invalid=20_000)
        t_heur += time.perf_counter() - t0
        h = found.best
        rows.append({
            "M": g.M, "N": g.N, "K": g.K,
            "tops_w_gain": ours.tops_per_w / h.tops_per_w,
            "gflops_gain": ours.gflops / h.gflops,
            "util_gain": ours.utilization / max(h.utilization, 1e-9),
        })
    derived = {
        "tops_w_gain_geomean": geomean(r["tops_w_gain"] for r in rows),
        "gflops_gain_geomean": geomean(r["gflops_gain"] for r in rows),
        "util_gain_geomean": geomean(r["util_gain"] for r in rows),
        "runtime_ours_s": round(t_ours, 3),
        "runtime_heuristic_s": round(t_heur, 3),
        "runtime_ratio": t_heur / max(t_ours, 1e-9),
    }
    return rows, derived


def fig9_primitive_scatter(n: int = 120, seed: int = 1):
    """Fig. 9: energy-efficiency vs throughput per primitive @ RF."""
    shapes = synthetic_dataset(n, seed=seed)
    rows = []
    for pname, prim in PRIMS.items():
        cfg = CiMSystemConfig(prim=prim, cim_level="RF")
        for g in shapes:
            m = sweep_evaluate(g, cfg)
            rows.append({"primitive": pname, "M": g.M, "N": g.N, "K": g.K,
                         "tops_per_w": m.tops_per_w, "gflops": m.gflops,
                         "utilization": m.utilization})
    best = {p: max(r["tops_per_w"] for r in rows if r["primitive"] == p)
            for p in PRIMS}
    gf = {p: max(r["gflops"] for r in rows if r["primitive"] == p)
          for p in PRIMS}
    return rows, {"best_tops_w": best, "max_gflops": gf}


def fig10_dimension_sweeps():
    """Fig. 10: metric trends vs weight/input/output matrix shapes."""
    rows = []
    sizes = [16, 32, 64, 128, 256, 512, 1024, 2048]
    for X in sizes:                      # (a) weight matrix N=K=X, vary M
        for M in sizes:
            m = sweep_evaluate(GEMM(M, X, X), D6_RF)
            rows.append({"sweep": "weight", "X": X, "var": M,
                         "tops_per_w": m.tops_per_w, "gflops": m.gflops,
                         "utilization": m.utilization})
    for X in sizes:                      # (b) input matrix M=K=X, vary N
        for N in sizes:
            m = sweep_evaluate(GEMM(X, N, X), D6_RF)
            rows.append({"sweep": "input", "X": X, "var": N,
                         "tops_per_w": m.tops_per_w, "gflops": m.gflops,
                         "utilization": m.utilization})
    for X in sizes:                      # (c) output matrix M=N=X, vary K
        for K in sizes:
            m = sweep_evaluate(GEMM(X, X, K), D6_RF)
            rows.append({"sweep": "output", "X": X, "var": K,
                         "tops_per_w": m.tops_per_w, "gflops": m.gflops,
                         "utilization": m.utilization})
    w512 = [r for r in rows if r["sweep"] == "weight" and r["X"] == 512]
    peak_m = max(w512, key=lambda r: r["tops_per_w"])
    out256 = [r for r in rows if r["sweep"] == "output"
              and r["var"] == 256]
    return rows, {"weight512_best_M": peak_m["var"],
                  "weight512_best_topsw": peak_m["tops_per_w"],
                  "k256_mean_topsw": statistics.mean(
                      r["tops_per_w"] for r in out256)}


def fig11_12_memory_levels():
    """Fig. 11/12: real workloads at RF vs SMEM (configA/B) vs baseline."""
    rows = []
    cfgs = {
        "RF": CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF"),
        "SMEM-A": CiMSystemConfig(
            prim=DIGITAL_6T, cim_level="SMEM",
            n_prims=CiMSystemConfig(prim=DIGITAL_6T,
                                    cim_level="RF").resolved_n_prims()),
        "SMEM-B": CiMSystemConfig(prim=DIGITAL_6T, cim_level="SMEM",
                                  n_prims=configb_count(DIGITAL_6T)),
    }
    for wl, gemms in REAL_WORKLOADS.items():
        for g in gemms:
            base = sweep_evaluate_baseline(g)
            row = {"workload": wl, "M": g.M, "N": g.N, "K": g.K,
                   "baseline_tops_w": base.tops_per_w,
                   "baseline_gflops": base.gflops}
            for name, cfg in cfgs.items():
                m = sweep_evaluate(g, cfg)
                row[f"{name}_tops_w"] = m.tops_per_w
                row[f"{name}_gflops"] = m.gflops
                row[f"{name}_util"] = m.utilization
            rows.append(row)
    bert = [r for r in rows if r["workload"] == "BERT-Large"]
    derived = {
        "bert_rf_vs_baseline_topsw": geomean(
            r["RF_tops_w"] / r["baseline_tops_w"] for r in bert),
        "smemB_vs_rf_gflops": geomean(
            r["SMEM-B_gflops"] / r["RF_gflops"] for r in rows
            if r["M"] > 1),
        "max_energy_gain": max(
            max(r["RF_tops_w"], r["SMEM-B_tops_w"]) / r["baseline_tops_w"]
            for r in rows),
        "max_throughput_gain": max(
            r["SMEM-B_gflops"] / r["baseline_gflops"] for r in rows),
    }
    return rows, derived


def fig13_square_gemms():
    """Appendix Fig. 13: square GEMMs, all primitives + tensor core."""
    rows = []
    for g in square_sweep(64, 8192):
        base = sweep_evaluate_baseline(g)
        row = {"X": g.M, "Tcore_fj_mac": 2e3 * base.energy_pj / g.ops,
               "Tcore_gflops": base.gflops}
        for pname, prim in PRIMS.items():
            for level, np_ in (("RF", None),
                               ("SMEM", configb_count(prim))):
                cfg = CiMSystemConfig(prim=prim, cim_level=level,
                                      n_prims=np_)
                m = sweep_evaluate(g, cfg)
                row[f"{pname}@{level}_fj_mac"] = 2 * m.fj_per_op
                row[f"{pname}@{level}_gflops"] = m.gflops
        rows.append(row)
    big = rows[-1]
    return rows, {
        "a2_rf_fj_mac_at_8192": big["Analog-8T@RF_fj_mac"],
        "a1_rf_fj_mac_at_8192": big["Analog-6T@RF_fj_mac"],
        "d1_rf_gflops_at_8192": big["Digital-6T@RF_gflops"],
        "a1_rf_gflops_at_8192": big["Analog-6T@RF_gflops"],
    }


def table6_workload_characteristics():
    """Table VI: #MACs and algorithmic reuse (exact transcription check)."""
    rows = []
    for wl, gemms in REAL_WORKLOADS.items():
        for g in gemms:
            rows.append({"workload": wl, "M": g.M, "N": g.N, "K": g.K,
                         "macs": g.macs,
                         "reuse": round(g.algorithmic_reuse, 3)})
    bert = next(r for r in rows if r["workload"] == "BERT-Large"
                and r["M"] == 512 and r["N"] == 1024 and r["K"] == 1024)
    return rows, {"bert_macs": bert["macs"], "bert_reuse": bert["reuse"]}
