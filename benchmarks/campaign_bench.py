"""Design-space campaign benchmark: streaming frontier determinism gates.

Runs a fixed mid-size campaign grid (mistral-nemo-12b x {train_4k,
decode_32k} x 4 prototypes x 3 precisions (INT8/INT4/FP8) x 3 levels x
2 scales x 2 order modes, 2880 points, grouped per GEMM so cross-chunk
front merging is load-bearing) and gates the properties the frontier
artifacts rest on:

  * determinism — two back-to-back runs on fresh engines must produce
    **byte-identical** frontier CSVs (the golden front test and the
    results/ artifacts assume repr-stable float32 metrics and
    enumeration-order-canonical emission; any nondeterminism shows up
    here first),
  * chunk parity — a chunk-streaming engine (chunk_rows=512, >= 2
    device chunks) must reproduce the whole-batch CSV byte for byte,
  * backend parity — the pallas sweep kernel must reproduce the
    vectorized CSV byte for byte,
  * certification — each workload cell's energy champion must pass the
    bitwise re-evaluation gate through the planner (certify_front).

Timings record the streaming run (points/s through the chunked engine)
and the reduction overhead so the trajectory tracks campaign throughput
PR over PR.

Results merge into BENCH_planner.json under the `campaign` block
(sweep_bench owns the other keys and preserves this one; $BENCH_PLANNER_OUT
overrides the path).  A run failing any gate is quarantined to *.failed
— the trusted trajectory entry is left untouched — and running this
module directly (as the CI `campaign-bench` job does) then exits
nonzero.

Run directly:  PYTHONPATH=src python -m benchmarks.campaign_bench
"""
from __future__ import annotations

import hashlib
import json
import os
import socket
import subprocess
import sys
import time
from datetime import datetime, timezone

import jax

from repro.core.campaign import (CampaignSpec, Constraint, certify_front,
                                 run_campaign)
from repro.core.sweep import SweepEngine

# same grid family as tests/golden/campaign_front.csv: big enough that
# the chunked run streams >= 2 chunks, small enough for a CI job
SPEC = CampaignSpec(
    workloads=(("mistral-nemo-12b", "train_4k"),
               ("mistral-nemo-12b", "decode_32k")),
    prototypes=("Analog-6T", "Analog-8T", "Digital-6T", "Digital-8T"),
    precisions=("int8", "int4", "fp8"),
    levels=("RF", "SMEM-A", "SMEM-B"),
    scales=(1.0, 4.0),
    order_modes=("exact", "greedy"),
)
CONTRACTS = (Constraint("area_bytes", "<=", 1e8),)
CHUNK_ROWS = 512
BLOCK_POINTS = 256


def _provenance() -> dict:
    try:
        # --dirty marks artifacts produced by uncommitted code: the bare
        # sha alone would claim a commit that cannot reproduce the run
        sha = subprocess.check_output(
            ["git", "describe", "--always", "--dirty"], text=True,
            stderr=subprocess.DEVNULL).strip()
    except Exception:
        sha = "unknown"
    return {"git_sha": sha,
            "host": socket.gethostname(),
            "timestamp_utc": datetime.now(timezone.utc)
            .strftime("%Y-%m-%dT%H:%M:%SZ"),
            "jax": jax.__version__,
            "device": jax.devices()[0].platform}


def _run(backend: str = "vectorized", chunk_rows: int | None = None):
    """(csv text, sha256, stats, seconds) of one fresh-engine run."""
    engine = SweepEngine(mesh=None, chunk_rows=chunk_rows)
    t0 = time.perf_counter()
    result = run_campaign(SPEC, CONTRACTS, engine=engine,
                          backend=backend, block_points=BLOCK_POINTS,
                          group_by="gemm")
    seconds = time.perf_counter() - t0
    text = result.csv_text()
    sha = hashlib.sha256(text.encode()).hexdigest()
    return result, text, sha, seconds


def campaign_speed(write_json: bool = True):
    # --- determinism gate: two cold runs, byte-identical CSVs
    res_a, text_a, sha_a, s_a = _run()
    _, text_b, sha_b, s_b = _run()
    determinism_ok = text_a == text_b

    # --- chunk parity: the streaming engine reproduces the whole batch
    res_c, text_c, sha_c, s_c = _run(chunk_rows=CHUNK_ROWS)
    chunk_tel = res_c.stats["engine_chunks"]
    chunk_parity_ok = text_c == text_a
    chunks_streamed_ok = chunk_tel["evaluated"] >= 2

    # --- backend parity: pallas == vectorized, byte for byte (on
    # platforms without a pallas lowering the engine falls back to the
    # XLA kernel, which must still reproduce the CSV)
    _, text_p, _, s_p = _run(backend="pallas")
    pallas_parity_ok = text_p == text_a

    # --- certification gate: every cell's energy champion re-evaluates
    # bitwise through the planner and still meets the contracts
    t0 = time.perf_counter()
    cert = certify_front(res_a, objectives=("energy_pj",))
    cert_s = time.perf_counter() - t0
    certification_ok = cert["ok"]

    gates = {
        "determinism_ok": determinism_ok,
        "chunk_parity_ok": chunk_parity_ok,
        "chunks_streamed_ok": chunks_streamed_ok,
        "pallas_parity_ok": pallas_parity_ok,
        "certification_ok": certification_ok,
    }
    for name, ok in gates.items():
        if not ok:
            print(f"WARNING: campaign bench gate {name} failed — "
                  f"quarantining this run", file=sys.stderr)

    n_points = res_a.stats["n_points"]
    block = {
        "grid": {"n_points": n_points,
                 "digest": SPEC.digest(),
                 "contracts": [c.spec() for c in CONTRACTS],
                 "group_by": "gemm"},
        "front_rows": len(res_a.front),
        "frontier_sha256": sha_a,
        "run_s": round(s_a, 3),
        "rerun_s": round(s_b, 3),
        "chunked_s": round(s_c, 3),
        "pallas_s": round(s_p, 3),
        "certify_s": round(cert_s, 3),
        "points_per_s": round(n_points / s_c, 1),
        "chunks": chunk_tel,
        "certified_points": len(cert["points"]),
        "gates": gates,
        "provenance": _provenance(),
    }
    rows = [{"backend": "campaign_vectorized", "seconds": round(s_a, 4)},
            {"backend": f"campaign_streamed_{chunk_tel['evaluated']}"
                        f"chunks_{CHUNK_ROWS}rows",
             "seconds": round(s_c, 4)},
            {"backend": "campaign_pallas", "seconds": round(s_p, 4)},
            {"backend": "campaign_certify", "seconds": round(cert_s, 4)}]

    if write_json:
        out = os.environ.get("BENCH_PLANNER_OUT", "BENCH_planner.json")
        # merge into the shared trajectory file: sweep_bench owns every
        # other key and preserves `campaign` symmetrically
        merged = {}
        if os.path.exists(out):
            try:
                with open(out) as f:
                    merged = json.load(f)
            except (json.JSONDecodeError, OSError):
                merged = {}
        merged["campaign"] = block
        if not all(gates.values()):
            # quarantine: leave the trusted entry untouched, park the
            # failing run (with its gate flags) next to it
            out += ".failed"
        with open(out, "w") as f:
            json.dump(merged, f, indent=1)
    return rows, block


if __name__ == "__main__":
    _, block = campaign_speed()
    print(json.dumps(block, indent=1))
    # CI runs this module directly: a determinism or parity break must
    # turn the job red, not just ship a quarantined artifact
    failed = [g for g, ok in block["gates"].items() if not ok]
    if failed:
        sys.exit(f"campaign bench gates failed: {', '.join(failed)} — "
                 f"artifact quarantined to *.failed (two back-to-back "
                 f"runs must produce byte-identical frontier CSVs, "
                 f"chunked + pallas runs must match them, and champions "
                 f"must certify bitwise)")
