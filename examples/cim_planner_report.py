"""WWW planner report over an assigned architecture: extract every GEMM of
qwen2-moe (train_4k and decode_32k), run the what/when/where analysis,
and print the per-GEMM verdicts — the paper's methodology applied to a
modern MoE LM.

  PYTHONPATH=src python examples/cim_planner_report.py
"""
from repro.configs import ARCHS, SHAPES
from repro.core import CiMSystemConfig, DIGITAL_6T, configb_count, decide
from repro.core.llm_workloads import gemms_of_model

cfgs = {
    "Digital-6T@RF": CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF"),
    "Digital-6T@SMEM-B": CiMSystemConfig(
        prim=DIGITAL_6T, cim_level="SMEM",
        n_prims=configb_count(DIGITAL_6T)),
}

arch = ARCHS["qwen2-moe-a2.7b"]
for shape_name in ("train_4k", "decode_32k"):
    shape = SHAPES[shape_name]
    gemms = gemms_of_model(arch, shape)
    # unique shapes, largest first
    uniq = {}
    for g in gemms:
        uniq.setdefault((g.M, g.N, g.K), g)
    top = sorted(uniq.values(), key=lambda g: -g.ops * g.count)[:8]
    print(f"\n=== {arch.name} x {shape_name} ({len(gemms)} GEMM kinds) ===")
    print(f"{'GEMM':38s} {'reuse':>8s} {'verdict':>20s}")
    for g in top:
        d = decide(g, cfgs)
        print(f"{str(g)[:38]:38s} {g.algorithmic_reuse:8.1f} "
              f"{d.what:>20s}")
