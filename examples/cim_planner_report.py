"""WWW planner report over an assigned architecture: extract every GEMM of
qwen2-moe (train_4k and decode_32k), run the what/when/where analysis,
and print the per-GEMM verdicts — the paper's methodology applied to a
modern MoE LM.

The whole report plans through the batched sweep engine: one
plan_workload call per shape evaluates every GEMM x config x candidate
mapping in a single fused device call (repro.core.sweep), instead of a
scalar cost-model call per option.

  PYTHONPATH=src python examples/cim_planner_report.py
"""
from repro.configs import ARCHS, SHAPES
from repro.core import (CiMSystemConfig, DIGITAL_6T, configb_count,
                        plan_workload, summarize)
from repro.core.llm_workloads import gemms_of_model
from repro.core.sweep import cache_info

cfgs = {
    "Digital-6T@RF": CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF"),
    "Digital-6T@SMEM-B": CiMSystemConfig(
        prim=DIGITAL_6T, cim_level="SMEM",
        n_prims=configb_count(DIGITAL_6T)),
}

arch = ARCHS["qwen2-moe-a2.7b"]
for shape_name in ("train_4k", "decode_32k"):
    shape = SHAPES[shape_name]
    gemms = gemms_of_model(arch, shape)
    # unique shapes, largest first
    uniq = {}
    for g in gemms:
        uniq.setdefault((g.M, g.N, g.K), g)
    top = sorted(uniq.values(), key=lambda g: -g.ops * g.count)[:8]
    decisions = plan_workload(top, cfgs, backend="vectorized")
    print(f"\n=== {arch.name} x {shape_name} ({len(gemms)} GEMM kinds) ===")
    print(f"{'GEMM':38s} {'reuse':>8s} {'verdict':>20s}")
    for d in decisions:
        g = d.gemm
        print(f"{str(g)[:38]:38s} {g.algorithmic_reuse:8.1f} "
              f"{d.what:>20s}")
    s = summarize(decisions)
    print(f"-- cim_fraction={s['cim_fraction']:.2f} "
          f"energy_gain={s['energy_gain_x']:.2f}x")
print(f"\nsweep cache: {cache_info()}")
