"""Serve a small model with batched requests and a KV cache, with the
planner-gated INT8 weight-stationary path on the prefill GEMMs.

  PYTHONPATH=src python examples/serve_decode.py
"""
import jax

from repro.configs import ARCHS, RunConfig, reduced
from repro.core import GEMM, decide
from repro.models import init
from repro.serving import ServeSession

cfg = reduced(ARCHS["mistral-nemo-12b"])
rc = RunConfig(attn_impl="naive", remat=False)
params = init(jax.random.PRNGKey(0), cfg)

# what/when/where for the FULL arch's dominant serving GEMMs (the tiny
# smoke model below serves; the planner reasons about production shapes)
full = ARCHS["mistral-nemo-12b"]
prefill_gemm = GEMM(1024, full.d_ff, full.d_model, label="prefill FFN")
decode_gemm = GEMM(4, full.d_ff, full.d_model, label="decode FFN (bs=4)")
for g in (prefill_gemm, decode_gemm):
    d = decide(g)
    print(f"{g.label:20s} -> {d.what} (use_cim={d.use_cim})")

sess = ServeSession(cfg, rc, params, max_len=64, batch=4, quantize=True)
for lab, r in sess.route_report().items():
    print(f"  {lab:10s} -> {r['route']} (use_cim={r['use_cim']})")
prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)
out = sess.generate(prompt, n_new=24, temperature=0.8, seed=7)
print("generated:", out.shape, "first row:",
      [int(x) for x in jax.device_get(out[0])[:12]],
      "decode executables:", sess.decode_executables)
