"""End-to-end driver (deliverable b): train a reduced qwen2 for a few
hundred steps on CPU with checkpointing and auto-resume.

  PYTHONPATH=src python examples/train_tiny_lm.py
"""
import tempfile

from repro.configs import ARCHS, RunConfig, reduced
from repro.data import DataConfig
from repro.train import train

cfg = reduced(ARCHS["qwen2-7b"])
rc = RunConfig(remat=False, attn_impl="naive", learning_rate=1e-3,
               warmup_steps=20)
dc = DataConfig(seed=0, vocab=cfg.vocab, seq_len=64, global_batch=8)

with tempfile.TemporaryDirectory() as ckpt_dir:
    res = train(cfg, rc, dc, n_steps=200, seed=0, ckpt_dir=ckpt_dir,
                ckpt_every=50)
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"over {len(res.losses)} steps")
    assert res.losses[-1] < res.losses[0], "model failed to learn"

    # auto-resume demo: a fresh call continues from the checkpoint
    res2 = train(cfg, rc, dc, n_steps=220, seed=0, ckpt_dir=ckpt_dir,
                 ckpt_every=50)
    print(f"auto-resumed from step {res2.resumed_from}; "
          f"final loss {res2.losses[-1]:.3f}")
