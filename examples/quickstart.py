"""Quickstart: the paper's what/when/where analysis in 30 lines.

Evaluates a BERT-Large GEMM and a decode GEMV on every CiM integration
point vs the tensor-core baseline, and prints the planner verdicts —
the paper's Table V, computed live.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (GEMM, decide, evaluate, evaluate_baseline,
                        CiMSystemConfig, DIGITAL_6T, ANALOG_8T)

bert_ffn = GEMM(512, 4096, 1024, label="BERT-Large FFN")
decode_gemv = GEMM(1, 16384, 4096, label="GPT-J decode FFN")

print("== raw cost model ==")
for g in (bert_ffn, decode_gemv):
    base = evaluate_baseline(g)
    cim = evaluate(g, CiMSystemConfig(prim=DIGITAL_6T, cim_level="RF"))
    print(f"{g.label:22s} baseline {base.tops_per_w:6.3f} TOPS/W "
          f"{base.gflops:7.1f} GF | Digital-6T@RF {cim.tops_per_w:6.3f} "
          f"TOPS/W {cim.gflops:7.1f} GF")

print("\n== planner (what / when / where) ==")
for g in (bert_ffn, decode_gemv):
    d = decide(g)
    print(f"{g.label:22s} what={d.what:18s} where={d.where:7s} "
          f"use_cim={d.use_cim}")

print("\nPaper takeaway reproduced: large-M GEMMs want CiM "
      "(weight-stationary reuse); M=1 decode GEMVs stay on the cores.")
